"""Serving semantics: O(1) state, determinism, batched generation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import lm


def _state_bytes(state):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(state)
               if hasattr(x, "size"))


def test_linear_decode_state_is_context_independent():
    """The paper's serving property: PRF decode state size does not grow
    with max context; exact-attention KV cache does."""
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    s1 = lm.init_serve_state(cfg, b=2, max_len=64)
    s2 = lm.init_serve_state(cfg, b=2, max_len=4096)
    assert _state_bytes(s1) == _state_bytes(s2)
    cfg_e = cfgs.darkify(cfg, "exact")
    e1 = lm.init_serve_state(cfg_e, b=2, max_len=64)
    e2 = lm.init_serve_state(cfg_e, b=2, max_len=4096)
    assert _state_bytes(e2) > 30 * _state_bytes(e1)


def test_decode_cost_independent_of_position():
    """Same decode_step jit signature regardless of how far in we are."""
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    st = lm.init_serve_state(cfg, b=1, max_len=128)
    tok = jnp.zeros((1,), jnp.int32)
    dec = jax.jit(lambda p, t, s: lm.decode_step(p, cfg, t, s))
    _, st = dec(params, tok, st)
    n0 = dec._cache_size()
    for _ in range(5):
        _, st = dec(params, tok, st)
    assert dec._cache_size() == n0      # no recompilation as pos advances


def test_greedy_generation_deterministic():
    cfg = cfgs.get_config("darkformer-2b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)

    def gen():
        lg, st = lm.prefill(params, cfg, {"tokens": toks}, max_len=32)
        out = [jnp.argmax(lg[:, -1], -1)]
        for _ in range(6):
            lg, st = lm.decode_step(params, cfg, out[-1], st)
            out.append(jnp.argmax(lg, -1))
        return jnp.stack(out, 1)

    np.testing.assert_array_equal(np.asarray(gen()), np.asarray(gen()))


def test_vlm_prefill_decode_positions():
    """VLM: decode positions continue after the patch prefix."""
    cfg = cfgs.get_config("internvl2-76b", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, Lt = 1, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Lt), 0, cfg.vocab)
    patches = 0.02 * jax.random.normal(
        jax.random.PRNGKey(2), (B, cfg.num_patches, cfg.d_model))
    batch = {"tokens": toks, "patch_embeds": patches,
             "labels": jnp.roll(toks, -1, 1)}
    full, _ = lm.forward_train(params, cfg, batch)
    lg, st = lm.prefill(params, cfg,
                        {"tokens": toks[:, :3], "patch_embeds": patches},
                        max_len=cfg.num_patches + Lt + 2)
    assert int(st["pos"]) == cfg.num_patches + 3
    maxerr = 0.0
    for t in range(3, Lt):
        lg, st = lm.decode_step(params, cfg, toks[:, t], st)
        tgt = full[:, cfg.num_patches + t]
        maxerr = max(maxerr, float(jnp.abs(lg - tgt).max()))
    assert maxerr < 0.08, maxerr
