"""Data pipelines: determinism, host-disjointness, learnable structure."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM, SyntheticAudio, SyntheticVLM, C4Mock


def test_synthetic_lm_deterministic():
    d1 = SyntheticLM(vocab=64, seq_len=16, batch_size=4, seed=3)
    d2 = SyntheticLM(vocab=64, seq_len=16, batch_size=4, seed=3)
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_synthetic_lm_hosts_disjoint():
    b0 = SyntheticLM(64, 16, 4, seed=3, host=0).batch(0)
    b1 = SyntheticLM(64, 16, 4, seed=3, host=1).batch(0)
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_synthetic_lm_learnable_structure():
    """Most labels must be in the successor set of the token (bigram)."""
    d = SyntheticLM(vocab=64, seq_len=64, batch_size=8, seed=0, noise=0.1)
    b = d.batch(0)
    succ = np.asarray(d._successors())
    toks = np.asarray(b["tokens"])[:, :-1]
    labs = np.asarray(b["labels"])[:, :-1]
    in_succ = (succ[toks] == labs[..., None]).any(-1)
    assert in_succ.mean() > 0.8


def test_labels_are_shifted_tokens():
    b = SyntheticLM(64, 16, 2, seed=1).batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_audio_batch_shapes():
    d = SyntheticAudio(d_model=32, seq_len=20, batch_size=3, vocab=17)
    b = d.batch(2)
    assert b["frames"].shape == (3, 20, 32)
    assert b["mask"].dtype == jnp.bool_
    assert int(b["labels"].max()) < 17


def test_vlm_batch_shapes():
    d = SyntheticVLM(d_model=16, num_patches=4, seq_len=12, batch_size=2,
                     vocab=50)
    b = d.batch(0)
    assert b["patch_embeds"].shape == (2, 4, 16)
    assert b["tokens"].shape == (2, 12)


def test_c4_mock_deterministic_and_shaped():
    d = C4Mock(vocab=256, seq_len=64, batch_size=2, seed=5)
    b1, b2 = d.batch(3), d.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 64)
    assert b1["tokens"].max() < 256
    b4 = d.batch(4)
    assert not np.array_equal(b1["tokens"], b4["tokens"])
