"""Continuous-batching engine semantics (repro/serving/).

The load-bearing claim (ISSUE acceptance + docs/serving.md): a sequence
decoded inside a busy heterogeneous batch — admitted into a reused slot,
surrounded by other sequences being admitted/evicted mid-decode — yields
bit-identical f32 greedy tokens to the same sequence decoded alone with
``lm.prefill`` + ``lm.decode_step``. Slot rows are computed elementwise
over the batch axis, so co-batching must not perturb numerics at all.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import lm
from repro.serving import Request, ServingEngine, slots as slot_ops


def _cfg(kind: str, **kw):
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg = cfgs.darkify(cfg, kind, cfg.attn.num_features)
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _params(cfg):
    return lm.init_params(jax.random.PRNGKey(0), cfg)


def _prompts(vocab, lengths, seed=1):
    key = jax.random.PRNGKey(seed)
    return [jax.random.randint(jax.random.fold_in(key, i), (l,), 0,
                               vocab).tolist()
            for i, l in enumerate(lengths)]


def _reference_greedy(params, cfg, prompt, n, max_len):
    """Single-sequence greedy decode: the ground truth the engine must hit."""
    lg, st = lm.prefill(params, cfg, {"tokens": jnp.asarray([prompt])},
                        max_len=max_len)
    toks = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(n - 1):
        lg, st = lm.decode_step(params, cfg, jnp.asarray(toks[-1:]), st)
        toks.append(int(jnp.argmax(lg[0])))
    return toks


@pytest.mark.parametrize("kind", ["darkformer", "performer", "exact"])
def test_engine_matches_reference_bit_for_bit(kind):
    """3 requests of different lengths over 2 slots: the third is only
    admitted once a slot frees mid-decode, so slots are reused and the
    batch is heterogeneous throughout — outputs must still be exact."""
    cfg = _cfg(kind)
    params = _params(cfg)
    lengths, gens = (5, 9, 7), (6, 3, 8)
    prompts = _prompts(cfg.vocab, lengths)
    refs = [_reference_greedy(params, cfg, p, n, max_len=48)
            for p, n in zip(prompts, gens)]

    eng = ServingEngine(params, cfg, max_slots=2, max_len=48)
    uids = [eng.submit(Request(prompt=p, max_new_tokens=n))
            for p, n in zip(prompts, gens)]
    got = {r.uid: r.tokens for r in eng.run()}
    for uid, ref in zip(uids, refs):
        assert got[uid] == ref, kind
    st = eng.stats
    assert st["admitted"] == st["finished"] == 3
    assert st["decode_slot_steps"] > st["decode_steps"]  # real co-batching


def test_engine_pallas_matches_reference_path():
    """Engine-level kernel parity: the same traffic decoded through the
    Pallas prf_decode_step / linear_attn_scan kernels must reproduce the
    pure-jnp engine's greedy streams (f32 kernels agree to ~1e-6 on
    logits, far below greedy argmax gaps)."""
    streams = {}
    for use_kernel in (False, True):
        cfg = _cfg("darkformer", use_kernel=use_kernel)
        params = _params(cfg)
        prompts = _prompts(cfg.vocab, (6, 11, 8))
        eng = ServingEngine(params, cfg, max_slots=2, max_len=48)
        uids = [eng.submit(Request(prompt=p, max_new_tokens=n))
                for p, n in zip(prompts, (5, 4, 6))]
        got = {r.uid: r.tokens for r in eng.run()}
        streams[use_kernel] = [got[u] for u in uids]
    assert streams[False] == streams[True]


def test_mid_decode_admission_and_eviction():
    """A request submitted while others are mid-decode joins a freed slot;
    cancelling an active request evicts it without disturbing the rest."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prompts = _prompts(cfg.vocab, (6, 6, 6))
    ref2 = _reference_greedy(params, cfg, prompts[2], 5, max_len=32)

    eng = ServingEngine(params, cfg, max_slots=2, max_len=32)
    uid0 = eng.submit(Request(prompt=prompts[0], max_new_tokens=30))
    uid1 = eng.submit(Request(prompt=prompts[1], max_new_tokens=30))
    for _ in range(3):
        eng.step()
    assert eng.num_active == 2
    # submit a third mid-decode; both slots busy -> it must wait
    uid2 = eng.submit(Request(prompt=prompts[2], max_new_tokens=5))
    eng.step()
    assert eng.num_active == 2
    # evict request 0 mid-decode -> request 2 takes over its slot
    res0 = eng.cancel(uid0)
    assert res0.cancelled and len(res0.tokens) >= 4
    finished = eng.run()
    got = {r.uid: r for r in finished}
    assert uid2 in got and uid1 in got
    # the late-admitted sequence still decodes exactly
    assert got[uid2].tokens == ref2


def test_slot_write_read_roundtrip():
    """write_slot/read_slot are inverse over the heterogeneous state tree
    (scanned-unit leaves slot-axis 1, pos/length slot-axis 0)."""
    cfg = _cfg("exact")  # exact has the richest state (caches + lengths)
    params = _params(cfg)
    pool = lm.init_serve_state(cfg, b=3, max_len=16, per_slot=True)
    _, st = lm.prefill(params, cfg,
                       {"tokens": jnp.asarray([_prompts(cfg.vocab, (7,))[0]])},
                       max_len=16)
    pool2 = slot_ops.write_slot(pool, st, jnp.int32(1))
    back = slot_ops.read_slot(pool2, jnp.int32(1))
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(st)[0],
            jax.tree_util.tree_flatten_with_path(back)[0]):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        np.testing.assert_array_equal(
            np.asarray(a).reshape(np.asarray(b).shape), np.asarray(b),
            err_msg=jax.tree_util.keystr(pa))
    # untouched slots stayed zero/frozen
    other = slot_ops.read_slot(pool2, jnp.int32(0))
    for leaf in jax.tree_util.tree_leaves(other):
        if leaf.dtype == jnp.int32:
            assert int(np.max(np.asarray(leaf))) == 0


def test_chunked_prefill_admission_matches_blocking_admission():
    """chunk_tokens splits admission into resumed prompt chunks; the
    k-stabilizer trajectory changes, so logits only agree to f32
    rounding — greedy streams must still match on this model."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prompts = _prompts(cfg.vocab, (13, 9))
    streams = {}
    for chunk in (None, 4):
        eng = ServingEngine(params, cfg, max_slots=2, max_len=48,
                            chunk_tokens=chunk)
        uids = [eng.submit(Request(prompt=p, max_new_tokens=6))
                for p in prompts]
        got = {r.uid: r.tokens for r in eng.run()}
        streams[chunk] = [got[u] for u in uids]
    assert streams[None] == streams[4]


def test_poisson_arrivals_respected():
    """Requests are not admitted before their arrival_time; the fast
    (realtime=False) runner skips idle gaps but keeps ordering."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prompts = _prompts(cfg.vocab, (5, 5))
    eng = ServingEngine(params, cfg, max_slots=4, max_len=32)
    eng.submit(Request(prompt=prompts[0], max_new_tokens=3,
                       arrival_time=0.0))
    eng.submit(Request(prompt=prompts[1], max_new_tokens=3,
                       arrival_time=10.0))  # far future
    eng.step()
    assert eng.num_active == 1              # second not arrived yet
    results = eng.run(realtime=False)       # clock-jumps over the gap
    assert len(results) + len([s for s in eng._slots if s]) >= 1
    all_res = results
    assert sum(1 for r in all_res if r.tokens) >= 1
    assert not eng.has_work
