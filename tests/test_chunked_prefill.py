"""Chunked-prefill scheduler + resumable prefill (ISSUE 2 tentpole).

Three layers of guarantee:
  * lm-level: chained ``prefill_chunk`` calls reproduce whole-prompt
    ``lm.prefill`` to f32 rounding for exact/performer/darkformer (the
    running k-stabilizer max changes the trajectory), and BIT-exactly
    when the whole prompt is one chunk;
  * engine-level: with ``chunk_tokens=N`` no more than N prompt tokens
    execute between consecutive batched decode steps, decode keeps
    making progress while a long prompt admits, and greedy streams match
    blocking admission;
  * edge paths: cancel of a mid-prefill (cursor > 0) request, admission
    against a full pool, per-request top_k / top_p sampling.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import lm
from repro.serving import Request, ServingEngine


def _cfg(kind: str, **kw):
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg = cfgs.darkify(cfg, kind, cfg.attn.num_features)
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _params(cfg):
    return lm.init_params(jax.random.PRNGKey(0), cfg)


def _prompt(vocab, l, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (l,), 0,
                              vocab).tolist()


def _reference_greedy(params, cfg, prompt, n, max_len):
    lg, st = lm.prefill(params, cfg, {"tokens": jnp.asarray([prompt])},
                        max_len=max_len)
    toks = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(n - 1):
        lg, st = lm.decode_step(params, cfg, jnp.asarray(toks[-1:]), st)
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def _chained_prefill(params, cfg, toks, schedule, max_len):
    st = lm.init_serve_state(cfg, b=1, max_len=max_len)
    lo = 0
    for t in schedule:
        lg, st = lm.prefill_chunk(params, cfg,
                                  {"tokens": toks[:, lo:lo + t]}, st)
        lo += t
    assert lo == toks.shape[1]
    return lg, st


# ---------------------------------------------------------------------------
# lm-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["darkformer", "performer", "exact"])
def test_chunked_prefill_matches_whole_prompt(kind):
    """Uneven chunk schedule == whole-prompt prefill to f32 rounding on
    both the last-position logits and every serve-state leaf."""
    cfg = _cfg(kind)
    params = _params(cfg)
    toks = jnp.asarray([_prompt(cfg.vocab, 13)])
    lg_full, st_full = lm.prefill(params, cfg, {"tokens": toks},
                                  max_len=32)
    lg, st = _chained_prefill(params, cfg, toks, (5, 4, 3, 1), max_len=32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_full[:, -1]),
                               atol=1e-4)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(st)[0],
            jax.tree_util.tree_flatten_with_path(st_full)[0]):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-4, err_msg=(kind, jax.tree_util.keystr(pa)))


@pytest.mark.parametrize("kind", ["darkformer", "performer", "exact"])
def test_single_chunk_prefill_is_bit_exact(kind):
    """One whole-prompt chunk from a fresh state IS lm.prefill, bitwise:
    same stabilizer trajectory, same code path."""
    cfg = _cfg(kind)
    params = _params(cfg)
    toks = jnp.asarray([_prompt(cfg.vocab, 11, seed=3)])
    lg_full, st_full = lm.prefill(params, cfg, {"tokens": toks},
                                  max_len=32)
    lg, st = _chained_prefill(params, cfg, toks, (11,), max_len=32)
    np.testing.assert_array_equal(np.asarray(lg),
                                  np.asarray(lg_full[:, -1]))
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(st)[0],
            jax.tree_util.tree_flatten_with_path(st_full)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=jax.tree_util.keystr(pa))


def test_chunked_prefill_pallas_path_matches_jnp():
    """cfg.use_kernel routes resumed chunks through the Pallas carry
    kernel; logits and state must track the pure-jnp path."""
    toks = None
    results = {}
    for use_kernel in (False, True):
        cfg = _cfg("darkformer", use_kernel=use_kernel)
        params = _params(cfg)
        if toks is None:
            toks = jnp.asarray([_prompt(cfg.vocab, 12, seed=5)])
        results[use_kernel] = _chained_prefill(params, cfg, toks,
                                               (5, 7), max_len=32)
    np.testing.assert_allclose(np.asarray(results[True][0]),
                               np.asarray(results[False][0]), atol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(results[True][1]),
                    jax.tree_util.tree_leaves(results[False][1])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_chunked_prefill_then_decode_matches_uninterrupted(
        kind="darkformer"):
    """Decode from a chunk-assembled state continues the sequence: the
    greedy stream equals the whole-prompt-prefill stream."""
    cfg = _cfg(kind)
    params = _params(cfg)
    prompt = _prompt(cfg.vocab, 14, seed=7)
    ref = _reference_greedy(params, cfg, prompt, 8, max_len=48)
    lgc, st = _chained_prefill(params, cfg, jnp.asarray([prompt]),
                               (6, 6, 2), max_len=48)
    toks = [int(jnp.argmax(lgc[0]))]
    for _ in range(7):
        lg, st = lm.decode_step(params, cfg, jnp.asarray(toks[-1:]), st)
        toks.append(int(jnp.argmax(lg[0])))
    assert toks == ref


# ---------------------------------------------------------------------------
# engine-level scheduler invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["darkformer", "exact"])
def test_engine_chunked_streams_match_blocking(kind):
    """Greedy token streams are invariant to the admission schedule."""
    cfg = _cfg(kind)
    params = _params(cfg)
    prompts = [_prompt(cfg.vocab, l, seed=10 + l) for l in (17, 9, 23)]
    streams = {}
    for chunk in (None, 5, 64):
        eng = ServingEngine(params, cfg, max_slots=2, max_len=64,
                            chunk_tokens=chunk)
        uids = [eng.submit(Request(prompt=p, max_new_tokens=6))
                for p in prompts]
        got = {r.uid: r.tokens for r in eng.run()}
        streams[chunk] = [got[u] for u in uids]
    assert streams[None] == streams[5], kind
    # chunk_tokens >= prompt_len: whole prompt in one chunk -> the very
    # same computation as blocking admission
    assert streams[None] == streams[64], kind


def test_engine_prefill_budget_and_decode_progress():
    """A long-prompt admission never runs more than chunk_tokens prompt
    tokens between consecutive decode steps, and the already-active
    sequence keeps emitting one token per step throughout."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    short = _prompt(cfg.vocab, 4, seed=20)
    long = _prompt(cfg.vocab, 33, seed=21)
    ref_short = _reference_greedy(params, cfg, short, 20, max_len=64)

    eng = ServingEngine(params, cfg, max_slots=2, max_len=64,
                        chunk_tokens=4)
    uid_s = eng.submit(Request(prompt=short, max_new_tokens=20))
    eng.step()                                  # short admits + decodes
    assert eng.num_active == 1
    uid_l = eng.submit(Request(prompt=long, max_new_tokens=4))
    # 33 tokens / chunk 4 -> 9 chunks; the long request must stay
    # mid-prefill for 8 steps while the short one decodes each step
    for n in range(8):
        eng.step()
        assert eng.num_active == 1, n
        assert eng.num_prefilling == 1, n
    eng.step()                                  # 9th chunk -> admitted
    assert eng.num_active == 2
    results = {r.uid: r for r in eng.run()}
    assert results[uid_s].tokens == ref_short
    st = eng.stats
    assert st["max_prefill_tokens_per_step"] <= 4
    assert st["prefill_chunks"] >= 10           # 1 (short) + 9 (long)
    assert st["prefill_tokens"] == len(short) + len(long)


def test_cancel_mid_prefill_frees_slot_and_leaves_others_untouched():
    """cancel() of a request with prefill cursor > 0 drops its staged
    state, frees the slot for the next admission, and does not perturb
    the active sequence."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    pa = _prompt(cfg.vocab, 5, seed=30)
    pb = _prompt(cfg.vocab, 29, seed=31)
    pc = _prompt(cfg.vocab, 7, seed=32)
    ref_a = _reference_greedy(params, cfg, pa, 16, max_len=48)
    ref_c = _reference_greedy(params, cfg, pc, 5, max_len=48)

    eng = ServingEngine(params, cfg, max_slots=2, max_len=48,
                        chunk_tokens=4)
    uid_a = eng.submit(Request(prompt=pa, max_new_tokens=16))
    eng.step()
    uid_b = eng.submit(Request(prompt=pb, max_new_tokens=8))
    eng.step()
    eng.step()                                  # b's cursor now 4..8
    slot_b = next(s for s in eng._slots
                  if s is not None and s.req.uid == uid_b)
    assert 0 < slot_b.cursor < len(pb)
    res_b = eng.cancel(uid_b)
    assert res_b.cancelled and res_b.tokens == []
    assert eng.num_prefilling == 0
    uid_c = eng.submit(Request(prompt=pc, max_new_tokens=5))
    got = {r.uid: r for r in eng.run()}
    assert got[uid_a].tokens == ref_a          # undisturbed by b's life
    assert got[uid_c].tokens == ref_c          # reused b's slot cleanly
    assert eng.stats["admitted"] == 2          # b never finished admission


def test_admission_waits_for_full_pool():
    """With one slot, the second request only admits after the first
    evicts — and still decodes exactly."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    p1 = _prompt(cfg.vocab, 9, seed=40)
    p2 = _prompt(cfg.vocab, 12, seed=41)
    refs = [_reference_greedy(params, cfg, p, 5, max_len=32)
            for p in (p1, p2)]
    eng = ServingEngine(params, cfg, max_slots=1, max_len=32,
                        chunk_tokens=4)
    uids = [eng.submit(Request(prompt=p, max_new_tokens=5))
            for p in (p1, p2)]
    eng.step()
    assert eng.num_active + eng.num_prefilling == 1   # pool full
    assert len(eng._queue) == 1                        # second one queued
    got = {r.uid: r.tokens for r in eng.run()}
    for uid, ref in zip(uids, refs):
        assert got[uid] == ref


# ---------------------------------------------------------------------------
# batched multi-admission prefill (ISSUE 3 tentpole)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["darkformer", "exact"])
def test_ragged_padded_chunk_matches_serial_rows(kind):
    """One padded (2, L) prefill_chunk with ragged valid_len advances each
    row exactly as its own unpadded B=1 chunk would (states + logits)."""
    cfg = _cfg(kind)
    params = _params(cfg)
    lens = (5, 3)
    prompts = [_prompt(cfg.vocab, l, seed=80 + l) for l in lens]
    # serial: each row alone, unpadded
    serial = [lm.prefill_chunk(params, cfg,
                               {"tokens": jnp.asarray([p])},
                               lm.init_serve_state(cfg, b=1, max_len=32,
                                                   per_slot=True))
              for p in prompts]
    # batched: rows padded to L=5, per-row valid lengths
    toks = np.zeros((2, max(lens)), np.int32)
    for r, p in enumerate(prompts):
        toks[r, :len(p)] = p
    st = lm.init_serve_state(cfg, b=2, max_len=32, per_slot=True)
    lg, st = lm.prefill_chunk(params, cfg, {"tokens": jnp.asarray(toks)},
                              st, valid_len=jnp.asarray(lens, jnp.int32))
    for r in range(2):
        np.testing.assert_allclose(np.asarray(lg[r]),
                                   np.asarray(serial[r][0][0]), atol=1e-4)
        for (pa, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(st)[0],
                jax.tree_util.tree_flatten_with_path(serial[r][1])[0]):
            axis = 1 if "units" in jax.tree_util.keystr(pa) else 0
            np.testing.assert_allclose(
                np.take(np.asarray(a, np.float32), [r], axis=axis),
                np.asarray(b, np.float32),
                atol=1e-4, err_msg=(kind, jax.tree_util.keystr(pa)))


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "rwkv6-7b"])
def test_ragged_chunk_recurrent_arch_matches_serial_rows(arch):
    """Masked RG-LRU / RWKV carries: a recurrent-arch padded ragged
    chunk advances every carry (rglru h/conv, rwkv wkv S / token
    shifts) exactly like unpadded per-row chunks."""
    cfg = cfgs.get_config(arch, reduced=True)
    params = _params(cfg)
    lens = (6, 2)
    prompts = [_prompt(cfg.vocab, l, seed=90 + l) for l in lens]
    serial = [lm.prefill_chunk(params, cfg,
                               {"tokens": jnp.asarray([p])},
                               lm.init_serve_state(cfg, b=1, max_len=32,
                                                   per_slot=True))
              for p in prompts]
    toks = np.zeros((2, max(lens)), np.int32)
    for r, p in enumerate(prompts):
        toks[r, :len(p)] = p
    st = lm.init_serve_state(cfg, b=2, max_len=32, per_slot=True)
    lg, st = lm.prefill_chunk(params, cfg, {"tokens": jnp.asarray(toks)},
                              st, valid_len=jnp.asarray(lens, jnp.int32))
    for r in range(2):
        np.testing.assert_allclose(np.asarray(lg[r]),
                                   np.asarray(serial[r][0][0]), atol=1e-4)
        for (pa, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(st)[0],
                jax.tree_util.tree_flatten_with_path(serial[r][1])[0]):
            axis = 1 if "units" in jax.tree_util.keystr(pa) else 0
            np.testing.assert_allclose(
                np.take(np.asarray(a, np.float32), [r], axis=axis),
                np.asarray(b, np.float32),
                atol=1e-4, err_msg=jax.tree_util.keystr(pa))


def test_ragged_exact_chunk_at_page_end_writes_correctly():
    """Regression: a padded chunk near the end of an exact-cache page has
    idx + l_pad > lmax; a dynamic-slice write would CLAMP its start and
    shift every valid key. The masked gather-scatter must land row b's
    valid_len[b] tokens at exactly [idx, idx + valid_len)."""
    cfg = _cfg("exact")
    params = _params(cfg)
    max_len = 16
    prompts = [_prompt(cfg.vocab, 15, seed=130),
               _prompt(cfg.vocab, 14, seed=131)]
    # serial: 12-token chunk then the remainder, each row alone
    serial = []
    for p in prompts:
        st = lm.init_serve_state(cfg, b=1, max_len=max_len, per_slot=True)
        _, st = lm.prefill_chunk(params, cfg,
                                 {"tokens": jnp.asarray([p[:12]])}, st)
        lg, st = lm.prefill_chunk(params, cfg,
                                  {"tokens": jnp.asarray([p[12:]])}, st)
        serial.append((lg, st))
    # batched: both rows to cursor 12, then a ragged (3, 2) tail padded
    # to l_pad=8 -> idx=12, 12 + 8 > 16 exercises the would-be clamp
    st = lm.init_serve_state(cfg, b=2, max_len=max_len, per_slot=True)
    _, st = lm.prefill_chunk(
        params, cfg, {"tokens": jnp.asarray([p[:12] for p in prompts])},
        st)
    tails = np.zeros((2, 8), np.int32)
    tails[0, :3] = prompts[0][12:]
    tails[1, :2] = prompts[1][12:]
    lg, st = lm.prefill_chunk(params, cfg, {"tokens": jnp.asarray(tails)},
                              st, valid_len=jnp.asarray([3, 2], jnp.int32))
    for r in range(2):
        np.testing.assert_allclose(np.asarray(lg[r]),
                                   np.asarray(serial[r][0][0]), atol=1e-4)
        for (pa, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(st)[0],
                jax.tree_util.tree_flatten_with_path(serial[r][1])[0]):
            axis = 1 if "units" in jax.tree_util.keystr(pa) else 0
            np.testing.assert_allclose(
                np.take(np.asarray(a, np.float32), [r], axis=axis),
                np.asarray(b, np.float32),
                atol=1e-4, err_msg=jax.tree_util.keystr(pa))


def test_full_valid_len_matches_unpadded():
    """valid_len == L on every row is mathematically the identity over
    the unpadded path — logits and states agree to f32 rounding (XLA may
    fuse the masked program differently, so bitwise equality is NOT the
    contract here; the engine's bit-exact path comes from passing
    valid_len=None whenever every packed row is full)."""
    for arch in ("smollm-135m", "recurrentgemma-2b", "rwkv6-7b"):
        cfg = cfgs.get_config(arch, reduced=True)
        params = _params(cfg)
        toks = jnp.asarray([_prompt(cfg.vocab, 7, seed=95)])
        st0 = lm.init_serve_state(cfg, b=1, max_len=32, per_slot=True)
        lg_a, st_a = lm.prefill_chunk(params, cfg, {"tokens": toks}, st0)
        lg_b, st_b = lm.prefill_chunk(params, cfg, {"tokens": toks}, st0,
                                      valid_len=jnp.asarray([7],
                                                            jnp.int32))
        np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                                   atol=1e-4)
        for (pa, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(st_a)[0],
                jax.tree_util.tree_flatten_with_path(st_b)[0]):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=1e-4, err_msg=(arch, jax.tree_util.keystr(pa)))


@pytest.mark.parametrize("kind", ["darkformer", "exact"])
def test_engine_batches_staged_admissions_into_one_call(kind):
    """With >= 2 admissions staged and chunk_tokens fixed, every step
    runs exactly ONE prefill-chunk call covering multiple rows, and the
    streams match the serial (prefill_rows=1) schedule."""
    cfg = _cfg(kind)
    params = _params(cfg)
    prompts = [_prompt(cfg.vocab, l, seed=100 + l) for l in (21, 18, 15)]

    eng = ServingEngine(params, cfg, max_slots=4, max_len=64,
                        chunk_tokens=8)
    uids = [eng.submit(Request(prompt=p, max_new_tokens=5))
            for p in prompts]
    calls_before = eng.stats["prefill_calls"]
    eng.step()               # 3 staged rows -> one (3, L) packed call
    st = eng.stats
    assert st["prefill_calls"] == calls_before + 1
    assert st["prefill_rows_max"] == 3
    assert st["prefill_chunks"] == 3             # one row-chunk each
    assert st["max_prefill_tokens_per_step"] <= 8
    got = {r.uid: r.tokens for r in eng.run()}
    st = eng.stats
    # budget 8 over 3 admissions -> every step advanced all staged rows
    # in one call; rows/call must exceed 1 on average
    assert st["prefill_rows_per_call"] > 1.0
    assert 0.0 < st["prefill_batch_occupancy"] <= 1.0
    assert "ttft_p50" in st and "ttft_p99" in st

    serial = ServingEngine(params, cfg, max_slots=4, max_len=64,
                           chunk_tokens=8, prefill_rows=1)
    uids_s = [serial.submit(Request(prompt=p, max_new_tokens=5))
              for p in prompts]
    got_s = {r.uid: r.tokens for r in serial.run()}
    assert [got[u] for u in uids] == [got_s[u] for u in uids_s], kind


def test_packer_coalesces_ragged_burst_to_full_occupancy():
    """ISSUE 4 satellite (ROADMAP open item): the packer grants every
    staged row the SAME pow-2 chunk, so a ragged admission burst packs
    into full buckets — prefill_batch_occupancy == 1.0 (zero padding
    waste) as long as rows' remainders cover their grants, while the
    per-step token budget stays <= chunk_tokens and streams still match
    the serial schedule."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    lens = (32, 16, 8, 8)                 # ragged burst, pow-2 remnants
    prompts = [_prompt(cfg.vocab, l, seed=140 + i)
               for i, l in enumerate(lens)]
    eng = ServingEngine(params, cfg, max_slots=4, max_len=64,
                        chunk_tokens=16)
    uids = [eng.submit(Request(prompt=p, max_new_tokens=4))
            for p in prompts]
    got = {r.uid: r.tokens for r in eng.run()}
    st = eng.stats
    assert st["prefill_batch_occupancy"] == 1.0
    assert st["max_prefill_tokens_per_step"] <= 16
    assert st["prefill_rows_per_call"] > 1.0

    serial = ServingEngine(params, cfg, max_slots=4, max_len=64,
                           chunk_tokens=16, prefill_rows=1)
    uids_s = [serial.submit(Request(prompt=p, max_new_tokens=4))
              for p in prompts]
    got_s = {r.uid: r.tokens for r in serial.run()}
    assert [got[u] for u in uids] == [got_s[u] for u in uids_s]


def test_engine_p1_unbucketed_matches_serial_bitwise():
    """prefill_rows=1 + bucket_prefill=False is the pre-batching
    scheduler: one unpadded chunk of the oldest admission per step —
    streams must equal the chunk-chained B=1 reference exactly."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prompt = _prompt(cfg.vocab, 19, seed=110)
    ref = _reference_greedy(params, cfg, prompt, 6, max_len=64)

    eng = ServingEngine(params, cfg, max_slots=2, max_len=64,
                        chunk_tokens=64, prefill_rows=1,
                        bucket_prefill=False)
    uid = eng.submit(Request(prompt=prompt, max_new_tokens=6))
    got = {r.uid: r.tokens for r in eng.run()}
    assert got[uid] == ref
    assert eng.stats["prefill_rows_max"] == 1


def test_blocking_mode_batches_all_pending_admissions():
    """chunk_tokens=None still admits every pending request in the step
    it arrives — now through one padded whole-prompt batched call."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prompts = [_prompt(cfg.vocab, l, seed=120 + l) for l in (9, 14)]
    refs = [_reference_greedy(params, cfg, p, 4, max_len=48)
            for p in prompts]
    eng = ServingEngine(params, cfg, max_slots=2, max_len=48)
    uids = [eng.submit(Request(prompt=p, max_new_tokens=4))
            for p in prompts]
    eng.step()
    st = eng.stats
    assert st["prefill_calls"] == 1 and st["prefill_rows_max"] == 2
    assert eng.num_active == 2
    got = {r.uid: r.tokens for r in eng.run()}
    for uid, ref in zip(uids, refs):
        assert got[uid] == ref


def test_submit_validates_vocab_and_budget():
    """Out-of-vocab ids and over-budget prompts fail loudly at submit()
    instead of clamping/overflowing inside jit."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    eng = ServingEngine(params, cfg, max_slots=1, max_len=16)
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request(prompt=[0, cfg.vocab]))
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request(prompt=[-1, 2]))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(prompt=_prompt(cfg.vocab, 16, seed=1)))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(prompt=[]))
    # boundary: max_len - 1 prompt tokens leave room for one new token
    uid = eng.submit(Request(prompt=_prompt(cfg.vocab, 15, seed=1),
                             max_new_tokens=8))
    res = {r.uid: r for r in eng.run()}[uid]
    assert len(res.tokens) == 1                 # budget-clamped


# ---------------------------------------------------------------------------
# per-request sampling params
# ---------------------------------------------------------------------------

def test_top_k_one_and_tiny_top_p_reduce_to_greedy():
    """top_k=1 (or a nucleus so small only the argmax survives) must
    reproduce the greedy stream even at temperature 1."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prompt = _prompt(cfg.vocab, 8, seed=50)
    ref = _reference_greedy(params, cfg, prompt, 6, max_len=32)
    for kw in ({"top_k": 1}, {"top_p": 1e-6}):
        eng = ServingEngine(params, cfg, max_slots=2, max_len=32)
        uid = eng.submit(Request(prompt=prompt, max_new_tokens=6,
                                 temperature=1.0, **kw))
        got = {r.uid: r.tokens for r in eng.run()}
        assert got[uid] == ref, kw


def test_sampling_defaults_change_nothing():
    """temperature>0 with default top_k/top_p must draw the same stream
    across runs: the per-row (uid, token-index) sample keys are
    schedule-invariant, so a pinned uid reproduces its draws exactly
    (and the top-k/p masks are identity at the defaults)."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prompt = _prompt(cfg.vocab, 8, seed=51)
    streams = []
    for _ in range(2):                        # deterministic across runs
        eng = ServingEngine(params, cfg, max_slots=2, max_len=32, seed=7)
        # pin the uid: it is folded into the per-step sample key
        uid = eng.submit(Request(prompt=prompt, max_new_tokens=6,
                                 temperature=0.8, uid=991))
        streams.append({r.uid: r.tokens for r in eng.run()}[uid])
    assert streams[0] == streams[1]
    assert len(streams[0]) == 6


def test_mixed_sampling_rows_in_one_batch():
    """Greedy and top-k rows co-batched: the greedy row must stay
    bit-identical to its solo reference."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    pg = _prompt(cfg.vocab, 6, seed=60)
    ps = _prompt(cfg.vocab, 7, seed=61)
    ref = _reference_greedy(params, cfg, pg, 8, max_len=32)
    eng = ServingEngine(params, cfg, max_slots=2, max_len=32)
    uid_g = eng.submit(Request(prompt=pg, max_new_tokens=8))
    eng.submit(Request(prompt=ps, max_new_tokens=8, temperature=1.0,
                       top_k=5, top_p=0.9))
    got = {r.uid: r.tokens for r in eng.run()}
    assert got[uid_g] == ref


def test_submit_rejects_degenerate_sampling_params():
    """top_p <= 0 would mask every token; reject at submit()."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    eng = ServingEngine(params, cfg, max_slots=1, max_len=32)
    p = _prompt(cfg.vocab, 4, seed=70)
    for kw in ({"top_p": 0.0}, {"top_p": -0.5}, {"top_k": -1},
               {"temperature": -1.0}):
        with pytest.raises(ValueError):
            eng.submit(Request(prompt=p, **kw))
