"""Multi-device tests: run in subprocesses with 8 fake host devices so the
main test process keeps seeing 1 device (per the dry-run contract)."""
import os
import subprocess
import sys
import textwrap

import pytest

# multi-device subprocesses / full launcher runs: minutes of
# wall-clock; skipped by scripts/check.sh --fast
pytestmark = pytest.mark.slow


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_grad_compression_shard_map():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map        # jax >= 0.6
        except ImportError:                  # jax 0.4.x
            from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_local_mesh
        from repro.parallel import compressed_psum_mean, init_error_feedback

        mesh = make_local_mesh(8, 1)
        g_local = jnp.stack([jnp.full((4,), float(i)) for i in range(8)])
        expect = np.full((4,), np.mean(range(8)), np.float32)

        def body_none(g):
            out, _ = compressed_psum_mean({"g": g[0]}, ("data",), "none")
            return out["g"][None]
        out = shard_map(body_none, mesh=mesh, in_specs=P("data", None),
                        out_specs=P("data", None))(g_local)
        np.testing.assert_allclose(np.asarray(out[0]), expect, rtol=1e-6)

        def body_bf16(g):
            out, _ = compressed_psum_mean({"g": g[0]}, ("data",), "bf16")
            return out["g"][None]
        out = shard_map(body_bf16, mesh=mesh, in_specs=P("data", None),
                        out_specs=P("data", None))(g_local)
        np.testing.assert_allclose(np.asarray(out[0]), expect, rtol=2e-2)

        eb = init_error_feedback({"g": g_local[0]})
        def body_int8(g, e):
            out, eb2 = compressed_psum_mean({"g": g[0]}, ("data",), "int8",
                                            {"g": e[0]})
            return out["g"][None], eb2["g"][None]
        out, eb2 = shard_map(body_int8, mesh=mesh,
                             in_specs=(P("data", None), P("data", None)),
                             out_specs=(P("data", None), P("data", None)))(
            g_local, jnp.broadcast_to(eb["g"], (8, 4)))
        np.testing.assert_allclose(np.asarray(out[0]), expect, rtol=0.05)
        print("COMPRESSION_OK")
    """))


def test_int8_error_feedback_converges():
    """Error feedback makes the *average over steps* unbiased: constant
    gradient reduced with int8+EF accumulates to the exact sum."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map        # jax >= 0.6
        except ImportError:                  # jax 0.4.x
            from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_local_mesh
        from repro.parallel import compressed_psum_mean

        mesh = make_local_mesh(8, 1)
        g_const = jnp.linspace(-1.0, 1.0, 4)

        def step(e):
            out, eb = compressed_psum_mean(
                {"g": g_const}, ("data",), "int8", {"g": e})
            return out["g"], eb["g"]

        def run(e0):
            tot = jnp.zeros(4)
            e = e0
            for _ in range(64):
                o, e = step(e)
                tot = tot + o
            return tot[None]

        tot = shard_map(run, mesh=mesh, in_specs=P("data", None),
                        out_specs=P("data", None))(jnp.zeros((8, 4)))
        np.testing.assert_allclose(np.asarray(tot[0, 0] / 64),
                                   np.asarray(g_const), atol=1e-3)
        print("EF_OK")
    """))


def test_pjit_train_step_multidevice():
    """The actual train step under a 4x2 (data, model) mesh: loss finite,
    params sharded per the rules, metrics replicated."""
    print(run_py("""
        import jax, jax.numpy as jnp
        from repro import configs as cfgs
        from repro.launch.mesh import make_local_mesh
        from repro.launch import steps as steps_lib
        from repro.models import lm
        from repro.optim import AdamWConfig, adamw_init
        from repro.optim.schedules import constant
        from repro.parallel import (param_specs, opt_state_specs,
                                    batch_specs, make_shardings)
        from repro.data import SyntheticLM

        cfg = cfgs.get_config("smollm-135m", reduced=True)
        mesh = make_local_mesh(4, 2)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = adamw_init(params, opt_cfg)
        pspecs = param_specs(params, mesh)
        pshard = make_shardings(pspecs, mesh)
        oshard = make_shardings(opt_state_specs(opt, pspecs, mesh), mesh)
        params = jax.tree_util.tree_map(jax.device_put, params, pshard)
        opt = jax.tree_util.tree_map(jax.device_put, opt, oshard)
        data = SyntheticLM(cfg.vocab, 32, 8)
        batch = dict(data.batch(0))
        bshard = make_shardings(batch_specs(batch, mesh), mesh)
        batch = jax.tree_util.tree_map(jax.device_put, batch, bshard)
        step = jax.jit(steps_lib.make_train_step(cfg, opt_cfg,
                                                 constant(1e-3)),
                       in_shardings=(pshard, oshard, bshard, None),
                       out_shardings=(pshard, oshard, None),
                       donate_argnums=(0, 1))
        p2, o2, m = step(params, opt, batch, jnp.int32(0))
        assert jnp.isfinite(m["loss"]), m
        # embed is sharded over (model, data) => 8 shards
        emb_sh = p2["embed"].sharding
        assert len(emb_sh.device_set) == 8
        print("PJIT_OK", float(m["loss"]))
    """))


def test_elastic_restore_across_topologies(tmp_path):
    """Checkpoint written from a 4x2 mesh reloads onto a 2x4 mesh
    (shrink/regrow path) with identical values."""
    print(run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro import checkpoint as ck
        from repro.launch.mesh import make_local_mesh, make_mesh_for_shape
        from repro.parallel import param_specs, make_shardings
        from repro import configs as cfgs
        from repro.models import lm
        from repro.runtime import elastic_shrink_plan

        cfg = cfgs.get_config("smollm-135m", reduced=True)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        mesh1 = make_local_mesh(4, 2)
        sh1 = make_shardings(param_specs(params, mesh1), mesh1)
        placed = jax.tree_util.tree_map(jax.device_put, params, sh1)
        ck.save_checkpoint(r'{tmp_path}', 0, placed)

        new_shape = elastic_shrink_plan((4, 2), ("data", "model"), 1,
                                        devices_per_host=2)
        assert new_shape == (2, 2), new_shape
        mesh2 = make_mesh_for_shape(new_shape, ("data", "model"))
        sh2 = make_shardings(param_specs(params, mesh2), mesh2)
        restored, step = ck.restore_to_shardings(r'{tmp_path}', params, sh2)
        for a, b in zip(jax.tree_util.tree_leaves(restored),
                        jax.tree_util.tree_leaves(params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))
        print("ELASTIC_OK")
    """))


def test_sequence_parallel_state_combine():
    """SP prefill: per-shard partial (S, z) combined with one psum equals
    the full-sequence state (associativity of the prefix state)."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map        # jax >= 0.6
        except ImportError:                  # jax 0.4.x
            from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_local_mesh
        from repro.core.linear_attention import (
            LinearState, sequence_parallel_state_combine)

        mesh = make_local_mesh(8, 1)
        L, m, dv = 64, 8, 4
        kf = jax.random.uniform(jax.random.PRNGKey(0), (L, m))
        v = jax.random.normal(jax.random.PRNGKey(1), (L, dv))
        s_full = kf.T @ v
        z_full = kf.sum(0)

        def shard_fn(kf_l, v_l):
            st = LinearState(kf_l.T @ v_l, kf_l.sum(0))
            st = sequence_parallel_state_combine(st, "data")
            return st.s, st.z

        s, z = shard_map(shard_fn, mesh=mesh,
                         in_specs=(P("data", None), P("data", None)),
                         out_specs=(P(None, None), P(None)))(kf, v)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_full),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(z), np.asarray(z_full),
                                   rtol=1e-5)
        print("SP_OK")
    """))


def test_sharded_slot_pool_decodes_token_identical():
    """ISSUE 3: the serving engine with a 2-device mesh (slot + staging
    pools device_put per serve_state_specs, constrained inside the jitted
    steps) streams token-identically to the unsharded engine, for both
    the PRF and the exact paged-KV kernels — and the pool really is
    sharded (2-device sharding on the batch axis)."""
    print(run_py("""
        import jax, numpy as np
        from repro import configs as cfgs
        from repro.launch.mesh import make_local_mesh
        from repro.models import lm
        from repro.serving import Request, ServingEngine

        for kind in ("darkformer", "exact"):
            cfg = cfgs.get_config("smollm-135m", reduced=True)
            cfg = cfgs.darkify(cfg, kind, cfg.attn.num_features)
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            prompts = [jax.random.randint(jax.random.PRNGKey(40 + l),
                                          (l,), 0, cfg.vocab).tolist()
                       for l in (9, 17, 6)]

            streams = {}
            for mesh in (None, make_local_mesh(2, 1),
                         make_local_mesh(2, 2)):
                eng = ServingEngine(params, cfg, max_slots=4, max_len=48,
                                    chunk_tokens=6, mesh=mesh)
                uids = [eng.submit(Request(prompt=p, max_new_tokens=8))
                        for p in prompts]
                got = {r.uid: r.tokens for r in eng.run()}
                key = "none" if mesh is None else str(mesh.shape)
                streams[key] = [got[u] for u in uids]
                if mesh is not None:
                    ndev = len(eng.pool["pos"].sharding.device_set)
                    assert ndev == mesh.size, (kind, ndev)
            ref = streams.pop("none")
            for key, s in streams.items():
                assert s == ref, (kind, key)
        print("SHARDED_POOL_OK")
    """))
