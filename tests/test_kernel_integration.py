"""End-to-end Pallas path: the full model with use_kernel=True must match
the pure-jnp path (forward + gradients) — proves the kernels integrate at
the framework level, not just in isolation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs as cfgs
from repro.models import lm, layers as ll


def test_model_with_pallas_attention_matches_jnp():
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg_k = dataclasses.replace(cfg, use_kernel=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1, m1 = lm.loss_fn(params, cfg, batch)
    l2, m2 = lm.loss_fn(params, cfg_k, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    g1 = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    g2 = jax.grad(lambda p: lm.loss_fn(p, cfg_k, batch)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4)


def test_model_pallas_prefill_matches():
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg_k = dataclasses.replace(cfg, use_kernel=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    l1, s1 = lm.prefill(params, cfg, {"tokens": toks}, max_len=32)
    l2, s2 = lm.prefill(params, cfg_k, {"tokens": toks}, max_len=32)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-3)


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 1000), st.integers(1, 4), st.sampled_from([1, 2, 4]))
def test_moe_output_in_expert_span(seed, top_k, e_div):
    """Property: each token's MoE output is a convex combination (gates sum
    to <=1 after capacity) of per-expert outputs — outputs stay bounded by
    the max expert-output norm."""
    e = 4 * e_div
    cfg = ll.MoEConfig(num_experts=e, top_k=top_k, d_ff=8,
                       capacity_factor=4.0)
    p = ll.moe_init(jax.random.PRNGKey(seed), 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 12, 8))
    out, aux = ll.moe_apply(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    # bound: ||out_t|| <= max_e ||f_e(x_t)||
    def expert_out(xt, ei):
        h = jax.nn.silu(xt @ p["w_gate"][ei]) * (xt @ p["w_up"][ei])
        return h @ p["w_out"][ei]
    norms = []
    for ei in range(e):
        eo = jax.vmap(lambda xt: expert_out(xt, ei))(x[0])
        norms.append(jnp.linalg.norm(eo, axis=-1))
    max_norm = jnp.max(jnp.stack(norms), axis=0)
    out_norm = jnp.linalg.norm(out[0], axis=-1)
    assert bool(jnp.all(out_norm <= max_norm + 1e-4))


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 1000))
def test_adamw_update_invariant_to_param_tree_structure(seed):
    """Property: optimizer treats tree structure transparently — updating
    {'a': w} equals updating {'nested': {'x': w}} leaf-wise."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    cfg = AdamWConfig(lr=0.01)
    w = jax.random.normal(jax.random.PRNGKey(seed), (4, 4))
    g = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, 4))
    p1, s1 = {"a": w}, adamw_init({"a": w}, cfg)
    p2, s2 = {"n": {"x": w}}, adamw_init({"n": {"x": w}}, cfg)
    n1, _, _ = adamw_update(p1, {"a": g}, s1, cfg, 0.01)
    n2, _, _ = adamw_update(p2, {"n": {"x": g}}, s2, cfg, 0.01)
    np.testing.assert_allclose(np.asarray(n1["a"]),
                               np.asarray(n2["n"]["x"]))
