"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.linear_attn_scan import linear_attention_causal_fwd
from repro.kernels.prf_featmap import prf_featmap_fwd


@pytest.mark.parametrize("n,l,m,dv,chunk", [
    (1, 8, 4, 4, 4),
    (4, 96, 32, 16, 32),
    (2, 128, 64, 32, 64),
    (3, 100, 16, 8, 32),          # non-divisible L -> padding path
    (2, 64, 48, 24, 64),          # chunk == L
])
def test_linear_attn_kernel_shapes(n, l, m, dv, chunk):
    key = jax.random.PRNGKey(l * 7 + m)
    kq, kk, kv = jax.random.split(key, 3)
    qf = jax.random.uniform(kq, (n, l, m))
    kf = jax.random.uniform(kk, (n, l, m))
    v = jax.random.normal(kv, (n, l, dv))
    out = linear_attention_causal_fwd(qf, kf, v, chunk=chunk,
                                      interpret=True)
    expect = ref.linear_attention_causal_ref(qf, kf, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_attn_kernel_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    qf = jax.random.uniform(kq, (2, 64, 16)).astype(dtype)
    kf = jax.random.uniform(kk, (2, 64, 16)).astype(dtype)
    v = jax.random.normal(kv, (2, 64, 8)).astype(dtype)
    out = linear_attention_causal_fwd(qf, kf, v, chunk=32, interpret=True)
    expect = ref.linear_attention_causal_ref(qf, kf, v)
    assert out.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


def test_linear_attn_gradients_match_oracle():
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    qf = jax.random.uniform(kq, (2, 48, 16))
    kf = jax.random.uniform(kk, (2, 48, 16))
    v = jax.random.normal(kv, (2, 48, 8))

    def l_kernel(q, k, v_):
        return jnp.sum(ops.linear_attention_causal(q, k, v_, chunk=16) ** 2)

    def l_ref(q, k, v_):
        return jnp.sum(ref.linear_attention_causal_ref(q, k, v_) ** 2)

    g1 = jax.grad(l_kernel, argnums=(0, 1, 2))(qf, kf, v)
    g2 = jax.grad(l_ref, argnums=(0, 1, 2))(qf, kf, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("n,d,r,m,blk", [
    (16, 8, 4, 16, 8),
    (70, 16, 8, 64, 32),          # padding path
    (128, 32, 32, 128, 64),
])
def test_featmap_kernel_dark(n, d, r, m, blk):
    key = jax.random.PRNGKey(n + d)
    kx, km, kw = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n, d))
    m_mat = 0.3 * jax.random.normal(km, (r, d))
    w = jax.random.normal(kw, (m, r))
    out = prf_featmap_fwd(x, m_mat, w, jnp.float32(0.7), block_n=blk,
                          interpret=True)
    expect = ref.prf_featmap_ref(x, m_mat, w, jnp.float32(0.7))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=1e-6)


def test_featmap_kernel_iso():
    key = jax.random.PRNGKey(3)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (40, 8))
    w = jax.random.normal(kw, (32, 8))
    out = prf_featmap_fwd(x, None, w, jnp.float32(0.0), block_n=16,
                          interpret=True)
    expect = ref.prf_featmap_ref(x, None, w, jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=1e-6)


def test_featmap_gradients():
    key = jax.random.PRNGKey(4)
    kx, km, kw = jax.random.split(key, 3)
    x = jax.random.normal(kx, (20, 8))
    m_mat = 0.3 * jax.random.normal(km, (4, 8))
    w = jax.random.normal(kw, (16, 4))

    def lk(m_):
        return jnp.sum(ops.prf_featmap(x, m_, w, 0.5, block_n=8) ** 2)

    def lr(m_):
        return jnp.sum(ref.prf_featmap_ref(x, m_, w,
                                           jnp.float32(0.5)) ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(lk)(m_mat)),
                               np.asarray(jax.grad(lr)(m_mat)), atol=1e-4)


def test_kernel_jit_and_vmap_compose():
    qf = jax.random.uniform(jax.random.PRNGKey(0), (2, 3, 32, 8))
    kf = jax.random.uniform(jax.random.PRNGKey(1), (2, 3, 32, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 32, 4))
    out = jax.jit(lambda a, b, c: ops.linear_attention_causal(
        a, b, c, chunk=16))(qf, kf, v)
    expect = ref.linear_attention_causal_ref(
        qf.reshape(6, 32, 8), kf.reshape(6, 32, 8), v.reshape(6, 32, 4)
    ).reshape(2, 3, 32, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)


def test_rglru_ref_matches_manual_loop():
    key = jax.random.PRNGKey(5)
    n, l, d = 2, 10, 4
    x = jax.random.normal(key, (n, l, d))
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 1),
                                         (n, l, d)))
    g = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 2),
                                         (n, l, d)))
    h0 = jnp.zeros((n, d))
    hs, hl = ref.rglru_ref(x, a, g, h0)
    h = np.zeros((n, d), np.float32)
    for t in range(l):
        at = np.asarray(a[:, t])
        it = np.sqrt(np.clip(1 - at * at, 0, None)) * np.asarray(
            g[:, t]) * np.asarray(x[:, t])
        h = at * h + it
        np.testing.assert_allclose(np.asarray(hs[:, t]), h, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), h, atol=1e-5)


def test_wkv6_ref_matches_manual_loop():
    key = jax.random.PRNGKey(6)
    n, l, dh = 2, 6, 4
    r = jax.random.normal(key, (n, l, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (n, l, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (n, l, dh))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 3),
                                         (n, l, dh)))
    u = 0.3 * jnp.ones((dh,))
    s0 = jnp.zeros((n, dh, dh))
    o, s_last = ref.wkv6_ref(r, k, v, w, u, s0)
    s = np.zeros((n, dh, dh), np.float32)
    for t in range(l):
        kv = np.asarray(k[:, t])[:, :, None] * np.asarray(v[:, t])[:, None]
        ot = np.einsum("nd,nde->ne", np.asarray(r[:, t]),
                       s + np.asarray(u)[None, :, None] * kv)
        np.testing.assert_allclose(np.asarray(o[:, t]), ot, atol=1e-5)
        s = np.asarray(w[:, t])[:, :, None] * s + kv
    np.testing.assert_allclose(np.asarray(s_last), s, atol=1e-5)


@pytest.mark.parametrize("n,l,dh,chunk", [
    (2, 16, 4, 8),
    (3, 50, 8, 16),          # padding path
    (1, 64, 16, 64),
])
def test_wkv6_kernel_vs_ref(n, l, dh, chunk):
    from repro.kernels.wkv6_scan import wkv6_fwd
    key = jax.random.PRNGKey(l + dh)
    r = jax.random.normal(key, (n, l, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (n, l, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (n, l, dh))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 3),
                                         (n, l, dh)) + 2.0)
    u = 0.3 * jnp.ones((dh,))
    out = wkv6_fwd(r, k, v, w, u, chunk=chunk, interpret=True)
    expect, _ = ref.wkv6_ref(r, k, v, w, u, jnp.zeros((n, dh, dh)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=3e-5)


def test_wkv6_ops_gradients():
    key = jax.random.PRNGKey(9)
    n, l, dh = 2, 24, 4
    r = jax.random.normal(key, (n, l, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (n, l, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (n, l, dh))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 3),
                                         (n, l, dh)) + 2.0)
    u = 0.3 * jnp.ones((dh,))

    def lk(r_):
        return jnp.sum(ops.wkv6(r_, k, v, w, u, chunk=8) ** 2)

    def lr(r_):
        o, _ = ref.wkv6_ref(r_, k, v, w, u, jnp.zeros((n, dh, dh)))
        return jnp.sum(o ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(lk)(r)),
                               np.asarray(jax.grad(lr)(r)), atol=2e-4)


# ---------------------------------------------------------------------------
# prf_decode_step: one-token serving update
# ---------------------------------------------------------------------------

from repro.kernels.prf_decode_step import prf_decode_step_fwd  # noqa: E402


@pytest.mark.parametrize("n,m,dv,block_b", [
    (1, 8, 4, 8),
    (16, 32, 16, 8),
    (13, 16, 8, 8),               # n % block_b != 0 -> padding path
    (6, 64, 32, 4),
    (3, 24, 12, 16),              # block_b > n -> clamped tile
])
def test_prf_decode_step_vs_ref(n, m, dv, block_b):
    key = jax.random.PRNGKey(n * 31 + m)
    kq, kk, kv, ks, kz, kr = jax.random.split(key, 6)
    qf = jax.random.uniform(kq, (n, m))
    kf = jax.random.uniform(kk, (n, m))
    v = jax.random.normal(kv, (n, dv))
    s = jax.random.normal(ks, (n, m, dv))
    z = jax.random.uniform(kz, (n, m)) + 0.5
    # online-stabilizer rescale in (0, 1] as produced by exp(c_old-c_new)
    rescale = jax.random.uniform(kr, (n, 1), minval=0.05, maxval=1.0)
    out, s_new, z_new = prf_decode_step_fwd(qf, kf, v, s, z, rescale,
                                            block_b=block_b,
                                            interpret=True)
    eo, es, ez = ref.prf_decode_step_ref(qf, kf, v, s, z, rescale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eo), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s_new), np.asarray(es),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(z_new), np.asarray(ez),
                               atol=2e-5)


def test_prf_decode_step_ops_wrapper_shapes():
    """ops.linear_attention_decode_step flattens (B,G,Hg) leads and
    broadcasts a (B,G,1) rescale across heads."""
    key = jax.random.PRNGKey(5)
    b, g, hg, m, dv = 2, 3, 2, 16, 8
    kq, kk, kv, ks, kz, kr = jax.random.split(key, 6)
    qf = jax.random.uniform(kq, (b, g, hg, m))
    kf = jax.random.uniform(kk, (b, g, hg, m))
    v = jax.random.normal(kv, (b, g, hg, dv))
    s = jax.random.normal(ks, (b, g, hg, m, dv))
    z = jax.random.uniform(kz, (b, g, hg, m)) + 0.5
    rescale = jax.random.uniform(kr, (b, g, 1), minval=0.1, maxval=1.0)
    out, s_new, z_new = ops.linear_attention_decode_step(
        qf, kf, v, s, z, rescale)
    assert out.shape == (b, g, hg, dv)
    assert s_new.shape == (b, g, hg, m, dv)
    assert z_new.shape == (b, g, hg, m)
    eo, es, ez = ref.prf_decode_step_ref(
        qf.reshape(-1, m), kf.reshape(-1, m), v.reshape(-1, dv),
        s.reshape(-1, m, dv), z.reshape(-1, m),
        jnp.broadcast_to(rescale, (b, g, hg)).reshape(-1, 1))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, dv),
                               np.asarray(eo), atol=2e-5)


# ---------------------------------------------------------------------------
# Carried-state (chunked prefill) scan kernel
# ---------------------------------------------------------------------------

from repro.kernels.linear_attn_scan import (  # noqa: E402
    linear_attention_causal_carry_fwd)
from repro.core import linear_attention as la  # noqa: E402


def _carry_inputs(n, l, m, dv, seed=0):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, ks, kz = jax.random.split(key, 5)
    qf = jax.random.uniform(kq, (n, l, m))
    kf = jax.random.uniform(kk, (n, l, m))
    v = jax.random.normal(kv, (n, l, dv))
    s0 = jax.random.normal(ks, (n, m, dv))
    z0 = jax.random.uniform(kz, (n, m)) * 4.0
    return qf, kf, v, s0, z0


@pytest.mark.parametrize("n,l,m,dv,chunk", [
    (2, 32, 16, 8, 16),
    (3, 37, 16, 8, 16),           # non-divisible L -> padding path
    (1, 8, 4, 4, 8),              # chunk == L
])
def test_carry_kernel_matches_oracle(n, l, m, dv, chunk):
    qf, kf, v, s0, z0 = _carry_inputs(n, l, m, dv, seed=l)
    out, s, z = linear_attention_causal_carry_fwd(
        qf, kf, v, s0, z0, chunk=chunk, interpret=True)
    eo, es, ez = ref.linear_attention_carry_ref(qf, kf, v, s0, z0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eo), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(es), atol=2e-5)
    np.testing.assert_allclose(np.asarray(z), np.asarray(ez), atol=2e-5)


def test_carry_kernel_zero_state_matches_fresh_kernel():
    """Seeding with zeros is exactly the fresh-sequence kernel."""
    qf, kf, v, _, _ = _carry_inputs(2, 48, 16, 8, seed=3)
    s0 = jnp.zeros((2, 16, 8))
    z0 = jnp.zeros((2, 16))
    out, _, _ = linear_attention_causal_carry_fwd(
        qf, kf, v, s0, z0, chunk=16, interpret=True)
    fresh = linear_attention_causal_fwd(qf, kf, v, chunk=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(fresh))


def test_carry_kernel_chained_chunks_match_single_pass():
    """Splitting a prompt into resumed chunks reproduces one full pass —
    the property the chunked-prefill scheduler rests on."""
    qf, kf, v, _, _ = _carry_inputs(2, 40, 16, 8, seed=5)
    s = jnp.zeros((2, 16, 8))
    z = jnp.zeros((2, 16))
    outs = []
    for lo, hi in ((0, 16), (16, 27), (27, 40)):   # uneven chunk schedule
        o, s, z = linear_attention_causal_carry_fwd(
            qf[:, lo:hi], kf[:, lo:hi], v[:, lo:hi], s, z,
            chunk=16, interpret=True)
        outs.append(o)
    full, sf, zf = ref.linear_attention_carry_ref(
        qf, kf, v, jnp.zeros((2, 16, 8)), jnp.zeros((2, 16)))
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, axis=1)),
                               np.asarray(full), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sf), atol=2e-5)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zf), atol=2e-5)


def test_jnp_carry_oracle_matches_masked_ref():
    """The pure-jnp chunked carry (core.linear_attention) agrees with the
    O(L^2) masked oracle on out and final state."""
    qf, kf, v, s0, z0 = _carry_inputs(2, 29, 16, 8, seed=7)
    out, s, z = la.linear_attention_causal_carry(qf, kf, v, s0, z0,
                                                 chunk=8)
    eo, es, ez = ref.linear_attention_carry_ref(qf, kf, v, s0, z0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(eo), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(es), atol=2e-5)
    np.testing.assert_allclose(np.asarray(z), np.asarray(ez), atol=2e-5)
