"""Lemma 3.1 / Theorem 3.2: variance formulas and optimality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import variance as vr


def _random_spd(key, d, lmax=0.45):
    evals = jax.random.uniform(key, (d,), minval=0.02, maxval=lmax)
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                           (d, d)))
    return (q * evals) @ q.T, evals, q


def test_variance_iso_closed_form_vs_mc():
    key = jax.random.PRNGKey(0)
    d = 6
    q = 0.3 * jax.random.normal(key, (d,))
    k = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (d,))
    om = jax.random.normal(jax.random.fold_in(key, 2), (500_000, d))
    z = jnp.exp(om @ (q + k) - 0.5 * (q @ q + k @ k))
    closed = float(vr.estimator_variance_iso(q, k))
    mc = float(jnp.var(z))
    assert abs(closed - mc) / closed < 0.1


def test_variance_is_closed_form_vs_mc():
    key = jax.random.PRNGKey(1)
    d = 5
    q = 0.3 * jax.random.normal(key, (d,))
    k = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (d,))
    sigma, _, _ = _random_spd(jax.random.fold_in(key, 2), d)
    sigma = sigma + 0.7 * jnp.eye(d)       # ensure A = I - S^-1/2 > 0
    chol = jnp.linalg.cholesky(sigma)
    om = jax.random.normal(jax.random.fold_in(key, 3), (500_000, d)) @ chol.T
    w = vr.importance_weight(om, jnp.eye(d)) / vr.importance_weight(
        om, sigma) * 0 + 1.0 / vr.importance_weight(om, sigma)
    # Z = (p_I / psi)(om) * prf terms; p_I/psi = 1 / w_sigma
    z = w * jnp.exp(om @ (q + k) - 0.5 * (q @ q + k @ k))
    closed = float(vr.estimator_variance_is(q, k, sigma))
    mc = float(jnp.var(z))
    assert abs(closed - mc) / max(closed, 1e-9) < 0.15


def test_theorem32_sigma_star_formula():
    """Sigma* = (I+2L)(I-2L)^{-1}: shares eigenbasis, matches eigenvalues."""
    key = jax.random.PRNGKey(2)
    d = 6
    lam, evals, evecs = _random_spd(key, d)
    star = vr.optimal_sigma_star(lam)
    expect = (evecs * ((1 + 2 * evals) / (1 - 2 * evals))) @ evecs.T
    np.testing.assert_allclose(np.asarray(star), np.asarray(expect),
                               rtol=1e-4, atol=1e-5)


def test_theorem32_iso_iff_iso():
    d = 5
    star_iso = vr.optimal_sigma_star(0.2 * jnp.eye(d))
    np.testing.assert_allclose(np.asarray(star_iso),
                               np.asarray(star_iso[0, 0] * jnp.eye(d)),
                               atol=1e-5)
    lam, _, _ = _random_spd(jax.random.PRNGKey(3), d)
    star = vr.optimal_sigma_star(lam)
    off = np.asarray(star - jnp.diag(jnp.diag(star)))
    assert np.abs(off).max() > 1e-3 or np.std(np.diag(star)) > 1e-3


def test_theorem32_optimality():
    """E[Var] under Sigma* < under I, and < under random proposals
    (Lemma 3.1 says Sigma* is the global optimum among proposals)."""
    key = jax.random.PRNGKey(4)
    d = 6
    lam, _, _ = _random_spd(key, d, lmax=0.4)
    star = vr.optimal_sigma_star(lam)
    v_iso = float(vr.expected_variance(jax.random.PRNGKey(5), lam, None))
    v_star = float(vr.expected_variance(jax.random.PRNGKey(5), lam, star))
    assert v_star < v_iso
    for i in range(3):
        pert, _, _ = _random_spd(jax.random.PRNGKey(10 + i), d)
        prop = star + 0.5 * pert + 0.6 * jnp.eye(d)
        v_p = float(vr.expected_variance(jax.random.PRNGKey(5), lam, prop))
        assert v_star <= v_p * 1.001


def test_b_gaussian_closed_form_vs_mc():
    key = jax.random.PRNGKey(6)
    d = 4
    lam, _, _ = _random_spd(key, d)
    om = 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (d,))
    chol = jnp.linalg.cholesky(lam)
    x = jax.random.normal(jax.random.fold_in(key, 2), (400_000, d)) @ chol.T
    mc = float(jnp.mean(jnp.exp(2 * x @ om - jnp.sum(x * x, -1))))
    closed = float(vr.b_gaussian(om, lam))
    assert abs(closed - mc) / closed < 0.05


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 10_000))
def test_variance_nonnegative_and_star_bounded(seed):
    key = jax.random.PRNGKey(seed)
    d = 4
    lam, _, _ = _random_spd(key, d)
    star = vr.optimal_sigma_star(lam)
    q = 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (d,))
    k = 0.3 * jax.random.normal(jax.random.fold_in(key, 2), (d,))
    v_iso = float(vr.estimator_variance_iso(q, k))
    v_is = float(vr.estimator_variance_is(q, k, star))
    assert v_iso >= -1e-6
    assert v_is >= -1e-6


def test_anisotropy_score():
    from repro.core.calibration import anisotropy_score
    key = jax.random.PRNGKey(7)
    iso = jax.random.normal(key, (4000, 16))
    aniso = iso * jnp.linspace(0.05, 3.0, 16)[None, :]
    assert float(anisotropy_score(iso)) < 0.1
    assert float(anisotropy_score(aniso)) > 0.25
