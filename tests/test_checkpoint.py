"""Checkpoint store: roundtrip, atomicity, GC, validation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 4)),
                       "b": jnp.zeros(4, jnp.bfloat16)},
            "opt": {"count": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    tree = _tree()
    ck.save_checkpoint(str(tmp_path), 7, tree)
    out, step = ck.restore_checkpoint(str(tmp_path), _tree(1))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_keep_k_gc(tmp_path):
    for s in range(6):
        ck.save_checkpoint(str(tmp_path), s, _tree(), keep=2)
    assert ck.all_steps(str(tmp_path)) == [4, 5]
    assert ck.latest_step(str(tmp_path)) == 5


def test_incomplete_checkpoint_ignored(tmp_path):
    ck.save_checkpoint(str(tmp_path), 1, _tree())
    # simulate a crash mid-write: directory without DONE marker
    broken = tmp_path / "step_9"
    broken.mkdir()
    (broken / "state.msgpack").write_bytes(b"garbage")
    assert ck.latest_step(str(tmp_path)) == 1
    out, step = ck.restore_checkpoint(str(tmp_path), _tree())
    assert step == 1


def test_shape_mismatch_raises(tmp_path):
    ck.save_checkpoint(str(tmp_path), 0, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ck.restore_checkpoint(str(tmp_path), {"w": jnp.zeros((3, 3))})


def test_missing_leaf_raises(tmp_path):
    ck.save_checkpoint(str(tmp_path), 0, {"w": jnp.zeros(2)})
    with pytest.raises(KeyError):
        ck.restore_checkpoint(str(tmp_path), {"w": jnp.zeros(2),
                                              "extra": jnp.zeros(1)})


def test_restore_to_shardings_single_device(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save_checkpoint(str(tmp_path), 0, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out, _ = ck.restore_to_shardings(str(tmp_path), tree, sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
