"""Attention-mode semantics: windows, softcap, RoPE thetas, GQA shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FeatureConfig, rf_attention
from repro.core.linear_attention import exact_attention
from repro.models import layers as ll
from repro.models import attention_block as ab


def test_sliding_window_masks_old_tokens():
    """A window-w query must ignore keys older than w positions."""
    key = jax.random.PRNGKey(0)
    B, G, Hg, L, d = 1, 1, 1, 16, 8
    q = jax.random.normal(key, (B, G, Hg, L, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, G, 1, L, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, G, 1, L, d))
    out_w = exact_attention(q, k, v, causal=True, window=4)
    # perturbing keys/values outside the window must not change outputs
    k2 = k.at[:, :, :, :8].set(99.0)
    v2 = v.at[:, :, :, :8].set(-99.0)
    out_w2 = exact_attention(q, k2, v2, causal=True, window=4)
    np.testing.assert_allclose(np.asarray(out_w[:, :, :, -4:]),
                               np.asarray(out_w2[:, :, :, -4:]), atol=1e-5)
    # ...but a full-causal attention DOES change
    out_full = exact_attention(q, k, v, causal=True)
    out_full2 = exact_attention(q, k2, v2, causal=True)
    assert float(jnp.abs(out_full[:, :, :, -4:]
                         - out_full2[:, :, :, -4:]).max()) > 1e-3


def test_causal_no_future_leakage():
    """Changing future tokens must not change past outputs (all kernels)."""
    key = jax.random.PRNGKey(1)
    B, G, Hg, L, d = 1, 1, 2, 12, 8
    from repro.core import init_feature_params
    q = jax.random.normal(key, (B, G, Hg, L, d)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, G, 1, L, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, G, 1, L, d))
    k2 = k.at[:, :, :, -1].add(3.0)
    v2 = v.at[:, :, :, -1].add(3.0)
    for kind in ("exact", "darkformer", "performer"):
        cfg = FeatureConfig(kind=kind, num_features=32)
        fp = (init_feature_params(jax.random.PRNGKey(2), cfg, d, 1)
              if kind != "exact" else None)
        o1 = rf_attention(q, k, v, fp, cfg)
        o2 = rf_attention(q, k2, v2, fp, cfg)
        np.testing.assert_allclose(np.asarray(o1[:, :, :, :-1]),
                                   np.asarray(o2[:, :, :, :-1]),
                                   atol=2e-4, err_msg=kind)


def test_logit_softcap_bounds_logits():
    from repro import configs as cfgs
    from repro.models import lm
    import dataclasses
    cfg = cfgs.get_config("recurrentgemma-2b", reduced=True)
    cfg = dataclasses.replace(cfg, logit_softcap=5.0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, _ = lm.forward_train(params, cfg, {"tokens": toks,
                                               "labels": toks})
    assert float(jnp.abs(logits).max()) <= 5.0 + 1e-4


def test_gqa_group_broadcast_matches_repeat():
    """GQA exact attention == repeating each KV head over its group."""
    key = jax.random.PRNGKey(3)
    B, G, Hg, L, d = 2, 2, 3, 10, 4
    q = jax.random.normal(key, (B, G, Hg, L, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, G, 1, L, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, G, 1, L, d))
    out = rf_attention(q, k, v, None, FeatureConfig(kind="exact"))
    kb = jnp.broadcast_to(k, (B, G, Hg, L, d))
    vb = jnp.broadcast_to(v, (B, G, Hg, L, d))
    out2 = rf_attention(q, kb[:, :, :1] * 0 + kb, vb, None,
                        FeatureConfig(kind="exact"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=1e-5)


@pytest.mark.parametrize("theta", [1e4, 1e6])
def test_rope_theta_long_range_distinguishes(theta):
    d = 32
    x = jnp.ones((1, 2, d))
    far = ll.apply_rope(x, jnp.array([0, 10_000]), theta)
    assert float(jnp.abs(far[0, 0] - far[0, 1]).max()) > 1e-3


def test_attn_block_projection_shapes():
    cfg = FeatureConfig(kind="darkformer", num_features=16)
    p = ab.attn_init(jax.random.PRNGKey(0), 32, 4, 2, 8, cfg,
                     qk_norm=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32))
    out = ab.attn_apply(p, x, cfg, n_heads=4, n_kv=2, d_head=8,
                        qk_norm=True)
    assert out.shape == (2, 6, 32)
    assert p["feat"]["w"].shape == (2, 16, 8)      # per-group features
    assert p["feat"]["m_mat"].shape == (2, 8, 8)


def test_w_frozen_m_trainable_contract():
    """Paper §6 trainability: performer/darkformer W frozen; lfk W trains;
    darkformer M trains."""
    from repro.core import init_feature_params
    key = jax.random.PRNGKey(4)
    B, G, Hg, L, d = 1, 1, 1, 8, 4
    q = jax.random.normal(key, (B, G, Hg, L, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, G, 1, L, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, G, 1, L, d))
    for kind, w_trains in (("performer", False), ("lfk", True),
                           ("darkformer", False)):
        cfg = FeatureConfig(kind=kind, num_features=8)
        fp = init_feature_params(jax.random.PRNGKey(5), cfg, d, 1)
        g = jax.grad(lambda f: jnp.sum(
            rf_attention(q, k, v, f, cfg) ** 2))(fp)
        wg = float(jnp.abs(g["w"]).max())
        assert (wg > 0) == w_trains, (kind, wg)
        if kind == "darkformer":
            assert float(jnp.abs(g["m_mat"]).max()) > 0
