"""RG-LRU and RWKV-6 mixers: streaming == full-sequence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import recurrent as rec


def test_rglru_streaming_equals_full():
    key = jax.random.PRNGKey(0)
    B, L, dm, dr = 2, 20, 16, 24
    params = rec.rglru_init(key, dm, dr)
    u = jax.random.normal(jax.random.PRNGKey(1), (B, L, dm)) * 0.5
    full, _ = rec.rglru_apply(params, u, None)
    o1, st = rec.rglru_apply(params, u[:, :7], None)
    outs = [o1]
    for t in range(7, L):
        o, st = rec.rglru_apply(params, u[:, t:t + 1], st)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stream),
                               atol=1e-5)


def test_rglru_decay_bounded():
    """a_t in (0,1) -> hidden state bounded for bounded input."""
    key = jax.random.PRNGKey(2)
    params = rec.rglru_init(key, 8, 8)
    u = jnp.ones((1, 500, 8))
    out, st = rec.rglru_apply(params, u, None)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.abs(st.h).max()) < 1e3


def test_rwkv6_streaming_equals_full():
    key = jax.random.PRNGKey(3)
    B, L, d, H = 2, 16, 16, 4
    params = rec.rwkv6_init(key, d, H)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, L, d)) * 0.5
    full, _ = rec.rwkv6_apply(params, x, H, None)
    o1, st = rec.rwkv6_apply(params, x[:, :5], H, None)
    outs = [o1]
    for t in range(5, L):
        o, st = rec.rwkv6_apply(params, x[:, t:t + 1], H, st)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stream),
                               atol=2e-4)


def test_rwkv6_channel_mix_token_shift():
    key = jax.random.PRNGKey(5)
    params = rec.rwkv6_channel_mix_init(key, 8, 16)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 10, 8))
    full, _ = rec.rwkv6_channel_mix(params, x, None)
    o1, last = rec.rwkv6_channel_mix(params, x[:, :4], None)
    o2, _ = rec.rwkv6_channel_mix(params, x[:, 4:], last)
    stream = jnp.concatenate([o1, o2], axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stream),
                               atol=1e-5)


def test_causal_conv_prefix():
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (1, 12, 4))
    w = jax.random.normal(jax.random.fold_in(key, 1), (4, 4))
    full, _ = rec._causal_conv(x, w, None)
    a, tail = rec._causal_conv(x[:, :6], w, None)
    b, _ = rec._causal_conv(x[:, 6:], w, tail)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([a, b], 1)),
                               atol=1e-5)
