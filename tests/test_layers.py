"""Layer substrate: norms, rope, mlp, MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import layers as ll


def test_rmsnorm_unit_rms():
    x = 5.0 * jax.random.normal(jax.random.PRNGKey(0), (4, 32))
    p = ll.rmsnorm_init(32)
    y = ll.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_layernorm_moments():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32)) * 3 + 2
    p = ll.layernorm_init(32)
    y = ll.layernorm(p, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0, atol=1e-2)


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 1000), st.integers(1, 32))
def test_rope_preserves_norm_and_relative_angle(seed, shift):
    """RoPE is orthogonal per position, and q.k depends only on the
    relative position (shift both -> same inner product)."""
    key = jax.random.PRNGKey(seed)
    d = 16
    q = jax.random.normal(key, (1, 8, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, d))
    pos = jnp.arange(8)
    q1 = ll.apply_rope(q, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(q1, axis=-1)),
                               np.asarray(jnp.linalg.norm(q, axis=-1)),
                               rtol=1e-4)
    k1 = ll.apply_rope(k, pos)
    q2 = ll.apply_rope(q, pos + shift)
    k2 = ll.apply_rope(k, pos + shift)
    ip1 = jnp.einsum("bld,bld->bl", q1, k1)
    ip2 = jnp.einsum("bld,bld->bl", q2, k2)
    np.testing.assert_allclose(np.asarray(ip1), np.asarray(ip2), atol=1e-3)


@pytest.mark.parametrize("kind", ["swiglu", "geglu", "gelu"])
def test_mlp_kinds(kind):
    p = ll.mlp_init(jax.random.PRNGKey(0), 16, 32, kind)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y = ll.mlp_apply(p, x, kind)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())


def test_moe_matches_dense_when_capacity_ample():
    cfg = ll.MoEConfig(num_experts=8, top_k=2, d_ff=16,
                       capacity_factor=4.0)
    p = ll.moe_init(jax.random.PRNGKey(0), 12, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 12))
    out, aux = ll.moe_apply(p, x, cfg)
    logits = x @ p["router"]
    gv, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    gv = gv / gv.sum(-1, keepdims=True)

    def per_tok(xt, it, gt):
        o = jnp.zeros(12)
        for kk in range(2):
            e = it[kk]
            h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
            o = o + gt[kk] * (h @ p["w_out"][e])
        return o

    expect = jax.vmap(jax.vmap(per_tok))(x, idx, gv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With tiny capacity, some tokens must be dropped (output ~ 0 for
    them), and outputs stay finite."""
    cfg = ll.MoEConfig(num_experts=4, top_k=1, d_ff=8,
                       capacity_factor=0.25)
    p = ll.moe_init(jax.random.PRNGKey(0), 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))
    out, _ = ll.moe_apply(p, x, cfg)
    assert not bool(jnp.isnan(out).any())
    row_norms = jnp.linalg.norm(out[0], axis=-1)
    assert float(jnp.min(row_norms)) < 1e-6      # dropped tokens exist


def test_moe_grads_flow_to_router_and_experts():
    cfg = ll.MoEConfig(num_experts=4, top_k=2, d_ff=8)
    p = ll.moe_init(jax.random.PRNGKey(0), 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))
    g = jax.grad(lambda pp: ll.moe_apply(pp, x, cfg)[0].sum()
                 + ll.moe_apply(pp, x, cfg)[1])(p)
    for k in ("router", "w_gate", "w_up", "w_out"):
        assert float(jnp.abs(g[k]).max()) > 0
