"""Fault tolerance: restart-equivalence, stragglers, elastic shrink."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (TrainSupervisor, SimulatedFailure,
                           StragglerMonitor, elastic_shrink_plan)


def _mk_step():
    def step_fn(state, step):
        return {"x": state["x"] + (step + 1),
                "steps_seen": state["steps_seen"] + 1}
    return step_fn


def test_supervisor_recovers_to_same_state(tmp_path):
    """Run with an injected failure == uninterrupted run (bit-identical)."""
    n = 20
    base = {"x": jnp.zeros(()), "steps_seen": jnp.zeros((), jnp.int32)}
    clean = TrainSupervisor(str(tmp_path / "clean"), ckpt_every=5).run(
        base, _mk_step(), n)
    faulty = TrainSupervisor(str(tmp_path / "faulty"), ckpt_every=5).run(
        base, _mk_step(), n, fail_at=12)
    assert float(clean["x"]) == float(faulty["x"]) == sum(
        range(1, n + 1))


def test_supervisor_resumes_across_runs(tmp_path):
    base = {"x": jnp.zeros(()), "steps_seen": jnp.zeros((), jnp.int32)}
    sup1 = TrainSupervisor(str(tmp_path), ckpt_every=5)
    s1 = sup1.run(base, _mk_step(), 10)     # checkpoints at 4, 9
    calls = []

    def counting_step(state, step):
        calls.append(step)
        return _mk_step()(state, step)

    sup2 = TrainSupervisor(str(tmp_path), ckpt_every=5)
    s2 = sup2.run(base, counting_step, 20)  # resumes from 9
    assert float(s2["x"]) == sum(range(1, 21))
    assert calls == list(range(10, 20))     # proof it resumed, not re-ran


def test_supervisor_gives_up_after_budget(tmp_path):
    base = {"x": jnp.zeros(())}

    def always_fail(state, step):
        raise SimulatedFailure("flaky host")

    sup = TrainSupervisor(str(tmp_path), max_restarts=2)
    with pytest.raises(SimulatedFailure):
        sup.run(base, always_fail, 5)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=3.0, warmup_steps=3)
    flags = []
    for i in range(10):
        flags.append(mon.record(i, 1.0))
    assert not any(flags)
    assert mon.record(10, 10.0)
    assert mon.straggler_steps == 1
    # EMA not polluted by the outlier
    assert not mon.record(11, 1.2)


@pytest.mark.parametrize("mesh,axes,failed,expect", [
    ((16, 16), ("data", "model"), 1, (8, 16)),
    ((16, 16), ("data", "model"), 17, (8, 16)),
    ((2, 16, 16), ("pod", "data", "model"), 1, (2, 8, 16)),
])
def test_elastic_shrink_plan(mesh, axes, failed, expect):
    assert elastic_shrink_plan(mesh, axes, failed) == expect


def test_elastic_shrink_too_small():
    with pytest.raises(ValueError):
        elastic_shrink_plan((2, 2), ("data", "model"), 2, devices_per_host=2)
