"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency (requirements-dev.txt). When it is
missing we must not kill collection of the whole suite — the MC / parity
tests in the same modules don't need it. Importing ``given``/``settings``/
``st`` from here yields the real thing when installed, and otherwise a stub
whose ``@given`` marks the test as skipped.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                            # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stub strategy factory: arguments are never drawn when skipped."""

        def __getattr__(self, name):
            def strategy(*_a, **_k):
                return None
            return strategy

    st = _Strategies()
