"""Overlapped serving loop (ISSUE 8 tentpole).

The pipelined scheduler (``ServingEngine(overlap=True)``) reorders WHEN
work is dispatched — decode first, prefill behind it, packing and
readback off the critical path — but runs the SAME jitted step
functions on the same states, so its token streams must be
bitwise-identical to the sequential reference scheduler. These tests
pin that contract under the adversarial schedules:

  * Poisson admission storms (greedy and sampled) across kernels and
    both chunked + blocking admission — every request's stream equal;
  * mid-stream cancellation triggered by the delayed ``on_token``
    stream itself (in-flight tokens of the victim are discarded in both
    modes), plus mid-prefill and queued cancels;
  * the solo bitwise reference, the chunk-budget invariant, the
    pipeline stats counters, ``flush()`` drain semantics, and the
    ``on_token`` readiness-order contract;
  * a mesh-sharded pool under forced multi-device (the deferred
    ``merge_slots`` scatter must commit correctly across shards) — runs
    in the multidevice CI job, skips at 1 device;
  * slots-level properties of the new primitives (``merge_slots``
    equals the read+write pair; ``PackBuffer`` really double-buffers).
"""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import lm
from repro.serving import Request, ServingEngine
from repro.serving import slots as slot_ops


def _cfg(kind: str, **kw):
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg = cfgs.darkify(cfg, kind, cfg.attn.num_features)
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _params(cfg):
    return lm.init_params(jax.random.PRNGKey(0), cfg)


def _storm(vocab, *, n=8, seed=0, rate=150.0, temperature=0.0,
           sampled_mix=False):
    """Poisson admission storm with PINNED uids so the per-row sample
    keys (and hence sampled streams) are comparable across engines."""
    rng = random.Random(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += rng.expovariate(rate)
        kw = {}
        if sampled_mix and i % 3 == 1:
            kw = {"top_k": 7, "top_p": 0.9}
        reqs.append(Request(
            prompt=[rng.randrange(vocab)
                    for _ in range(rng.randint(6, 30))],
            max_new_tokens=rng.randint(3, 9), arrival_time=t,
            temperature=temperature, uid=5000 + i, **kw))
    return reqs


def _run(params, cfg, reqs, *, overlap, chunk=16, slots=3, max_len=48,
         mesh=None):
    eng = ServingEngine(params, cfg, max_slots=slots, max_len=max_len,
                        chunk_tokens=chunk, seed=0, overlap=overlap,
                        mesh=mesh)
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    return {r.uid: list(r.tokens) for r in res}, eng


# ---------------------------------------------------------------------------
# stream equality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,chunk", [("darkformer", 16),
                                        ("darkformer", None),
                                        ("exact", 16)])
def test_overlap_matches_sequential_greedy_storm(kind, chunk):
    """Greedy Poisson storm: every request's emitted tokens must be
    bitwise-identical between the sequential and overlapped schedulers,
    for chunked AND blocking admission, PRF and exact-KV kernels."""
    cfg = _cfg(kind)
    params = _params(cfg)
    seq, _ = _run(params, cfg, _storm(cfg.vocab, seed=1),
                  overlap=False, chunk=chunk)
    ovl, _ = _run(params, cfg, _storm(cfg.vocab, seed=1),
                  overlap=True, chunk=chunk)
    assert set(seq) == set(ovl)
    for uid in seq:
        assert seq[uid] == ovl[uid], uid
    assert any(len(t) > 0 for t in seq.values())


def test_overlap_matches_sequential_sampled_storm():
    """Sampled storm (temperature 0.8, a third of the rows with
    top-k/top-p): the per-row (uid, token-index) sample keys are
    schedule-invariant, so even stochastic streams match bitwise."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    mk = lambda: _storm(cfg.vocab, seed=2, temperature=0.8,
                        sampled_mix=True)
    seq, _ = _run(params, cfg, mk(), overlap=False)
    ovl, _ = _run(params, cfg, mk(), overlap=True)
    for uid in seq:
        assert seq[uid] == ovl[uid], uid


def test_overlap_matches_solo_reference():
    """One request through the overlapped engine == the solo
    whole-prompt prefill + decode_step chain, bit-for-bit."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (8,), 0,
                                cfg.vocab).tolist()
    lg, st = lm.prefill(params, cfg, {"tokens": jnp.asarray([prompt])},
                        max_len=48)
    ref = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(5):
        lg, st = lm.decode_step(params, cfg, jnp.asarray(ref[-1:]), st)
        ref.append(int(jnp.argmax(lg[0])))
    got, _ = _run(params, cfg,
                  [Request(prompt=prompt, max_new_tokens=6, uid=77)],
                  overlap=True, chunk=None)
    assert got[77] == ref


# ---------------------------------------------------------------------------
# cancellation and eviction
# ---------------------------------------------------------------------------

def _run_cancel_trace(params, cfg, overlap):
    """Cancel a mid-decode request the moment its OBSERVED stream
    reaches 3 tokens (via on_token, i.e. at host readiness — in overlap
    mode more tokens are already in flight on device and must be
    dropped), one request while still queued, and one mid-prefill."""
    eng = ServingEngine(params, cfg, max_slots=2, max_len=96,
                        chunk_tokens=8, seed=0, overlap=overlap)
    reqs = _storm(cfg.vocab, n=4, seed=4)
    victim = reqs[0]
    seen = []

    def hook(tok, t):
        seen.append(tok)
        if len(seen) == 3:
            eng.cancel(victim.uid)
    victim.on_token = hook
    long = Request(prompt=[1] * 64, max_new_tokens=4,
                   arrival_time=0.0, uid=6000)   # several chunks long
    queued = Request(prompt=[2] * 8, max_new_tokens=4,
                     arrival_time=1e6, uid=6001)  # never arrives
    for r in [long, queued] + reqs:
        eng.submit(r)
    eng.step()                      # long admitted, mid-prefill
    assert eng.num_prefilling >= 1
    res_long = eng.cancel(long.uid)
    res_q = eng.cancel(queued.uid)
    done = {r.uid: list(r.tokens) for r in eng.run()}
    done.update({r.uid: list(r.tokens) for r in eng.flush()})
    return seen, res_long, res_q, done


def test_cancel_equality_and_discard():
    cfg = _cfg("darkformer")
    params = _params(cfg)
    out = [_run_cancel_trace(params, cfg, overlap)
           for overlap in (False, True)]
    (seen_a, long_a, q_a, done_a), (seen_b, long_b, q_b, done_b) = out
    # the victim observed exactly 3 tokens in BOTH modes: overlap's
    # in-flight tokens were discarded, not flushed
    assert len(seen_a) == len(seen_b) == 3
    assert seen_a == seen_b
    # mid-prefill cancel: no tokens ever emitted, slot freed
    for long_res in (long_a, long_b):
        assert long_res.cancelled and long_res.tokens == []
    assert q_a.cancelled and q_b.cancelled
    # survivors' streams are unaffected and identical across modes
    assert set(done_a) == set(done_b)
    for uid in done_a:
        assert done_a[uid] == done_b[uid], uid


# ---------------------------------------------------------------------------
# pipeline invariants, stats, drain
# ---------------------------------------------------------------------------

def test_overlap_stats_and_chunk_budget():
    """Overlap stats must surface the scheduler flag and the per-step
    pipeline counters, and the chunk-tokens budget invariant must hold
    under the pipelined dispatch too."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    _, eng = _run(params, cfg, _storm(cfg.vocab, seed=5), overlap=True,
                  chunk=16)
    st = eng.stats
    assert st["overlap"] is True
    assert st["max_prefill_tokens_per_step"] <= 16
    for key in ("decode_stall_ms_p50", "decode_stall_ms_p99",
                "decode_stall_ms_max", "dispatch_depth_mean",
                "dispatch_depth_max"):
        assert isinstance(st[key], (int, float)), key
    # the device queue ran ahead of the fetched buffer at least once
    # (the whole point of the pipeline)
    assert st["dispatch_depth_max"] >= 1
    _, eng_seq = _run(params, cfg, _storm(cfg.vocab, seed=5),
                      overlap=False, chunk=16)
    assert eng_seq.stats["overlap"] is False


def test_on_token_readiness_order():
    """on_token fires once per generated token, at host readiness, with
    non-decreasing times matching the recorded token_times."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    calls = []
    req = Request(prompt=[3] * 8, max_new_tokens=5, uid=81,
                  on_token=lambda tok, t: calls.append((tok, t)))
    got, _ = _run(params, cfg, [req], overlap=True)
    assert [tok for tok, _ in calls] == got[81]
    times = [t for _, t in calls]
    assert times == sorted(times)


def test_flush_drains_inflight():
    """After flush(), every token produced so far is host-visible even
    though the engine still has work; flush on the sequential engine is
    a no-op."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    eng = ServingEngine(params, cfg, max_slots=2, max_len=48,
                        chunk_tokens=16, seed=0, overlap=True)
    uid = eng.submit(Request(prompt=[5] * 8, max_new_tokens=12, uid=91))
    for _ in range(4):
        eng.step()
    slot = next(s for s in eng._slots if s is not None)
    assert slot.emitted > len(slot.result.tokens)   # tokens in flight
    eng.flush()
    assert slot.emitted == len(slot.result.tokens)  # all retired
    assert eng.has_work                             # request unfinished
    res = eng.run()
    assert len({r.uid: r for r in res}[uid].tokens) == 12

    eng_seq = ServingEngine(params, cfg, max_slots=2, max_len=48,
                            seed=0)
    assert eng_seq.flush() == []


# ---------------------------------------------------------------------------
# slots-level primitives
# ---------------------------------------------------------------------------

def test_merge_slots_matches_read_write_pair():
    """merge_slots == write_slots(dst, read_slots(src, idx), idx) on
    every leaf of a real serve-state pytree."""
    cfg = _cfg("darkformer")
    src = lm.init_serve_state(cfg, b=4, max_len=16, per_slot=True,
                              stacked=lm.can_stack_layers(cfg))
    dst = jax.tree_util.tree_map(lambda x: x + 1 if x.dtype != bool
                                 else x, src)
    idx = jnp.asarray([0, 2], jnp.int32)
    merged = slot_ops.merge_slots(dst, src, idx)
    ref = slot_ops.write_slots(dst, slot_ops.read_slots(src, idx), idx)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(merged)[0],
            jax.tree_util.tree_flatten_with_path(ref)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(pa))


def test_pack_buffer_double_buffers():
    """Consecutive packs land in DIFFERENT backing buffers (the view
    handed out for chunk N survives packing chunk N+1) and rows are
    zero-padded to l_pad."""
    pb = slot_ops.PackBuffer(max_rows=3, max_chunk=8)
    a = pb.pack([[1, 2, 3], [4]], 4)
    a_copy = a.copy()
    b = pb.pack([[9, 9, 9, 9]], 4)
    np.testing.assert_array_equal(a, a_copy)      # untouched by pack #2
    np.testing.assert_array_equal(a, [[1, 2, 3, 0], [4, 0, 0, 0]])
    np.testing.assert_array_equal(b, [[9, 9, 9, 9]])
    c = pb.pack([[7, 8]], 2)                      # reuses buffer of `a`
    assert c.base is a.base
    np.testing.assert_array_equal(b, [[9, 9, 9, 9]])


# ---------------------------------------------------------------------------
# mesh-sharded pool (multidevice CI job)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (multidevice CI job)")
def test_overlap_mesh_sharded_pool():
    """Overlapped scheduler over a mesh-sharded slot pool: the deferred
    merge_slots commit and the token-feed scatter must preserve stream
    equality with the unsharded sequential engine."""
    from repro.launch.mesh import make_local_mesh
    cfg = _cfg("darkformer")
    params = _params(cfg)
    mesh = make_local_mesh(2, 1)
    seq, _ = _run(params, cfg, _storm(cfg.vocab, n=6, seed=6),
                  overlap=False, slots=4)
    ovl, eng = _run(params, cfg, _storm(cfg.vocab, n=6, seed=6),
                    overlap=True, slots=4, mesh=mesh)
    for uid in seq:
        assert seq[uid] == ovl[uid], uid
    assert eng.stats["overlap"] is True
