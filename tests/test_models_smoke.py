"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, assert shapes + no NaNs; decoder
archs additionally check prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import lm


def _batch_for(cfg, B=2, L=16):
    key = jax.random.PRNGKey(1)
    if cfg.modality == "audio":
        return {
            "frames": jax.random.normal(key, (B, L, cfg.d_model)),
            "mask": jax.random.bernoulli(jax.random.fold_in(key, 1), 0.4,
                                         (B, L)),
            "labels": jax.random.randint(jax.random.fold_in(key, 2),
                                         (B, L), 0, cfg.vocab)}
    if cfg.modality == "vlm":
        toks = jax.random.randint(key, (B, L), 0, cfg.vocab)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
                "patch_embeds": 0.02 * jax.random.normal(
                    jax.random.fold_in(key, 3),
                    (B, cfg.num_patches, cfg.d_model))}
    toks = jax.random.randint(key, (B, L), 0, cfg.vocab)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}


@pytest.mark.parametrize("arch", cfgs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = cfgs.get_config(arch, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    logits, aux = lm.forward_train(params, cfg, batch)
    L = batch["labels"].shape[1]
    exp_positions = L + (cfg.num_patches if cfg.modality == "vlm" else 0)
    assert logits.shape == (2, exp_positions, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = lm.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [a for a in cfgs.ARCHS
                                  if cfgs.get_config(a, reduced=True).causal])
def test_smoke_prefill_decode_consistency(arch):
    cfg = cfgs.get_config(arch, reduced=True)
    if cfg.moe is not None:
        # capacity is sequence-length dependent; equality between the full
        # forward and prefill+decode only holds when nothing is dropped in
        # either path — force ample capacity for the consistency check.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    B, L = 2, 12
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, B, L)
    logits_full, _ = lm.forward_train(params, cfg, batch)
    half = L // 2
    pre_batch = {k: (v[:, :half] if k in ("tokens", "labels") else v)
                 for k, v in batch.items() if k != "labels"}
    lg, st = lm.prefill(params, cfg, pre_batch, max_len=L + 4)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]),
        np.asarray(logits_full[:, (cfg.num_patches if cfg.modality == "vlm"
                                   else 0) + half - 1]),
        atol=0.05, rtol=0.05)
    maxerr = 0.0
    for t in range(half, L):
        lg, st = lm.decode_step(params, cfg, batch["tokens"][:, t], st)
        tgt = logits_full[:, (cfg.num_patches if cfg.modality == "vlm"
                              else 0) + t]
        maxerr = max(maxerr, float(jnp.abs(lg - tgt).max()))
    assert maxerr < 0.08, f"decode drift {maxerr}"


@pytest.mark.parametrize("arch", cfgs.ASSIGNED)
def test_full_config_geometry(arch):
    """The FULL configs match the assigned table exactly (no allocation:
    eval_shape only)."""
    table = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256_000),
        "smollm-135m": (30, 576, 9, 3, 1536, 49_152),
        "granite-8b": (36, 4096, 32, 8, 14_336, 49_152),
        "qwen3-32b": (64, 5120, 64, 8, 25_600, 151_936),
        "yi-34b": (60, 7168, 56, 8, 20_480, 64_000),
        "rwkv6-7b": (32, 4096, 64, 64, 14_336, 65_536),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49_155),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151_936),
        "internvl2-76b": (80, 8192, 64, 8, 28_672, 128_256),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }
    cfg = cfgs.get_config(arch)
    nl, dm, nh, nkv, dff, vocab = table[arch]
    assert cfg.n_layers == nl and cfg.d_model == dm
    assert cfg.n_heads == nh and cfg.n_kv == nkv
    assert cfg.d_ff == dff and cfg.vocab == vocab
    pshape = jax.eval_shape(
        lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    n_params = sum(int(x.size) for x in jax.tree_util.tree_leaves(pshape))
    expected_scale = {
        "recurrentgemma-2b": 2.7e9, "smollm-135m": 1.35e8,
        "granite-8b": 8e9, "qwen3-32b": 3.2e10, "yi-34b": 3.4e10,
        "rwkv6-7b": 7e9, "granite-moe-3b-a800m": 3.3e9,
        "qwen3-moe-235b-a22b": 2.35e11, "internvl2-76b": 7e10,
        "hubert-xlarge": 1e9}[arch]
    assert 0.4 * expected_scale < n_params < 2.6 * expected_scale, \
        f"{arch}: {n_params/1e9:.2f}B params vs expected ~{expected_scale/1e9:.1f}B"


def test_moe_configs_match_table():
    g = cfgs.get_config("granite-moe-3b-a800m")
    assert g.moe.num_experts == 40 and g.moe.top_k == 8
    q = cfgs.get_config("qwen3-moe-235b-a22b")
    assert q.moe.num_experts == 128 and q.moe.top_k == 8
    assert q.qk_norm


def test_hybrid_pattern_ratio():
    cfg = cfgs.get_config("recurrentgemma-2b")
    kinds = cfg.layer_kinds()
    assert kinds.count("local") * 2 <= kinds.count("rec") + 2
    assert cfg.window == 2048


def test_kernel_switch_is_pure_config_change():
    """Paper finetuning scenario: exact checkpoint -> PRF kernel, same
    params except the feature params appear."""
    cfg_e = cfgs.get_config("smollm-135m", reduced=True)
    cfg_e = cfgs.darkify(cfg_e, "exact")
    cfg_d = cfgs.darkify(cfg_e, "darkformer", 32)
    p_e = lm.init_params(jax.random.PRNGKey(0), cfg_e)
    p_d = lm.init_params(jax.random.PRNGKey(0), cfg_d)
    leaves_e = {jax.tree_util.keystr(k)
                for k, _ in jax.tree_util.tree_flatten_with_path(p_e)[0]}
    leaves_d = {jax.tree_util.keystr(k)
                for k, _ in jax.tree_util.tree_flatten_with_path(p_d)[0]}
    extra = leaves_d - leaves_e
    assert extra and all("feat" in k for k in extra)
