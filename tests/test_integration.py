"""End-to-end launcher tests (subprocess, CPU, reduced configs)."""
import json
import os
import subprocess
import sys

import pytest

# multi-device subprocesses / full launcher runs: minutes of
# wall-clock; skipped by scripts/check.sh --fast
pytestmark = pytest.mark.slow


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cmd(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-m"] + args,
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=ROOT)
    assert out.returncode == 0, out.stdout[-3000:] + "\n" + out.stderr[-3000:]
    return out.stdout


def test_train_launcher_loss_decreases(tmp_path):
    mfile = tmp_path / "metrics.json"
    run_cmd(["repro.launch.train", "--arch", "smollm-135m", "--reduced",
             "--steps", "60", "--batch", "8", "--seq", "64",
             "--lr", "3e-3", "--log-every", "5",
             "--metrics-out", str(mfile)])
    metrics = json.load(open(mfile))
    first, last = metrics[0], metrics[-1]
    assert last["loss"] < first["loss"] - 0.1, (first, last)
    assert all(m["loss"] == m["loss"] for m in metrics)     # no NaN


def test_train_checkpoint_restart_failure_injection(tmp_path):
    """Injected failure mid-run: final metrics equal the clean run."""
    clean = tmp_path / "clean"
    faulty = tmp_path / "faulty"
    m1 = tmp_path / "m1.json"
    m2 = tmp_path / "m2.json"
    common = ["repro.launch.train", "--arch", "smollm-135m", "--reduced",
              "--steps", "30", "--batch", "4", "--seq", "32",
              "--ckpt-every", "10", "--log-every", "29"]
    run_cmd(common + ["--ckpt-dir", str(clean), "--metrics-out", str(m1)])
    run_cmd(common + ["--ckpt-dir", str(faulty), "--metrics-out", str(m2),
                      "--simulate-failure-at", "15"])
    a = json.load(open(m1))[-1]
    b = json.load(open(m2))[-1]
    assert abs(a["loss"] - b["loss"]) < 1e-4, (a, b)


def test_finetune_from_checkpoint_and_qkv_only(tmp_path):
    ck = tmp_path / "pretrain"
    run_cmd(["repro.launch.train", "--arch", "smollm-135m", "--reduced",
             "--kernel", "exact", "--steps", "12", "--batch", "4",
             "--seq", "32", "--ckpt-dir", str(ck), "--ckpt-every", "6"])
    # finetune with the PRF kernel from the exact-attention checkpoint is
    # exercised at the API level in test_finetune_api (param trees differ);
    # here: resume same kernel with qkv-only freezing.
    m = tmp_path / "m.json"
    run_cmd(["repro.launch.train", "--arch", "smollm-135m", "--reduced",
             "--kernel", "exact", "--steps", "6", "--batch", "4",
             "--seq", "32", "--finetune-from", str(ck), "--qkv-only",
             "--metrics-out", str(m)])
    assert json.load(open(m))


def test_serve_launcher_decodes():
    """The serve CLI drives the continuous-batching engine: more requests
    than slots, heterogeneous lengths, full stats report."""
    out = run_cmd(["repro.launch.serve", "--arch", "smollm-135m",
                   "--reduced", "--requests", "3", "--slots", "2",
                   "--prompt-len", "8-16", "--gen", "8",
                   "--max-len", "48"])
    assert "throughput:" in out and "slot occupancy:" in out
    assert out.count("req ") == 3


def test_serve_launcher_hybrid():
    out = run_cmd(["repro.launch.serve", "--arch", "recurrentgemma-2b",
                   "--reduced", "--requests", "2", "--slots", "2",
                   "--prompt-len", "12", "--gen", "6",
                   "--max-len", "32", "--kernel", "darkformer"])
    assert "throughput:" in out


def test_qkv_only_freeze_semantics():
    """qkv-only training changes ONLY wq/wk/wv/m_mat leaves."""
    import jax
    import jax.numpy as jnp
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro import configs as cfgs
    from repro.launch import steps as steps_lib
    from repro.models import lm
    from repro.optim import AdamWConfig, adamw_init
    from repro.optim.schedules import constant
    from repro.data import SyntheticLM

    cfg = cfgs.get_config("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)
    step = steps_lib.make_train_step(cfg, opt_cfg, constant(1e-2),
                                     freeze=steps_lib.qkv_only_freeze)
    batch = dict(SyntheticLM(cfg.vocab, 32, 4).batch(0))
    p2, _, _ = jax.jit(step)(params, opt, batch, jnp.int32(0))
    flat1 = jax.tree_util.tree_flatten_with_path(params)[0]
    flat2 = jax.tree_util.tree_flatten_with_path(p2)[0]
    for (path, a), (_, b) in zip(flat1, flat2):
        ps = jax.tree_util.keystr(path)
        changed = bool(jnp.any(a != b))
        trainable = any(k in ps for k in ("['wq']", "['wk']", "['wv']",
                                          "['m_mat']"))
        assert changed == trainable, (ps, changed, trainable)


def test_finetune_api_exact_to_darkformer():
    """The paper's main scenario: pretrained exact-attention weights are
    reused under the darkformer kernel (config change + feat params init),
    and finetuning improves loss."""
    import jax
    import jax.numpy as jnp
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro import configs as cfgs
    from repro.launch import steps as steps_lib
    from repro.models import lm
    from repro.optim import AdamWConfig, adamw_init
    from repro.optim.schedules import constant
    from repro.data import SyntheticLM

    cfg_e = cfgs.darkify(cfgs.get_config("smollm-135m", reduced=True),
                         "exact")
    p_exact = lm.init_params(jax.random.PRNGKey(0), cfg_e)
    cfg_d = cfgs.darkify(cfg_e, "darkformer", 32)
    p_dark = lm.init_params(jax.random.PRNGKey(0), cfg_d)
    # transplant every shared leaf (checkpoint surgery)
    flat_e = {jax.tree_util.keystr(k): v for k, v in
              jax.tree_util.tree_flatten_with_path(p_exact)[0]}
    flat_d, tdef = jax.tree_util.tree_flatten_with_path(p_dark)
    merged = [flat_e.get(jax.tree_util.keystr(k), v) for k, v in flat_d]
    p_dark = jax.tree_util.tree_unflatten(tdef, merged)
    data = SyntheticLM(cfg_d.vocab, 32, 8)
    opt_cfg = AdamWConfig(lr=3e-3)
    opt = adamw_init(p_dark, opt_cfg)
    step = jax.jit(steps_lib.make_train_step(cfg_d, opt_cfg,
                                             constant(3e-3)))
    losses = []
    for i in range(25):
        p_dark, opt, m = step(p_dark, opt, dict(data.batch(i)),
                              jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
