"""Fused data-aligned PRF decode megakernel (ISSUE 4 tentpole).

Four layers of guarantee, all in interpret mode on CPU:

  * kernel vs oracle: ``prf_fused_decode_fwd`` == ``ref.prf_fused_
    decode_ref`` across kinds, GQA geometries, non-divisible slot
    blocks and the stabilize=False path (incl. hypothesis sweeps);
  * kernel vs the jnp decode path: the fused one-call decode equals
    ``rf_attention_decode(use_kernel=False)`` (projection composed the
    other way round) to f32 rounding, step by step over a whole decode
    SEQUENCE — the stabilizer-trajectory contract — and matches the
    resumed-prefill reference;
  * aliasing: the pallas_call carries ``input_output_aliases`` mapping
    the (c, s, z) pool inputs onto the state outputs, so a donated pool
    is updated in place (no second pool-sized allocation);
  * layer-stacked decode: ``init_serve_state(stacked=True)`` +
    ``decode_step`` reproduce the per-unit layout exactly, and refuse
    heterogeneous patterns.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs as cfgs
from repro.core import attention as rfa
from repro.core import feature_maps as fm
from repro.kernels import ops, ref
from repro.kernels.prf_fused_decode import prf_fused_decode_fwd
from repro.models import lm


def _fused_inputs(b, g, hg, d, r, m, dv, dark, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    q = jax.random.normal(ks[0], (b, g, hg, d))
    k = jax.random.normal(ks[1], (b, g, d))
    v = jax.random.normal(ks[2], (b, g, dv))
    m_mat = 0.4 * jax.random.normal(ks[3], (g, r, d)) if dark else None
    w = jax.random.normal(ks[4], (g, m, r if dark else d))
    a = (jnp.einsum("gmr,grd->gdm", w, m_mat) if dark
         else jnp.swapaxes(w, -1, -2))
    s = jax.random.normal(ks[5], (b, g, hg, m, dv))
    z = jax.random.uniform(ks[6], (b, g, hg, m)) + 0.5
    c = jax.random.normal(ks[7], (b, g))
    return q, k, v, a, m_mat, s, z, c


@pytest.mark.parametrize("b,g,hg,d,r,m,dv,dark,stab,block_b", [
    (1, 1, 1, 4, 2, 8, 4, True, True, 8),
    (4, 2, 2, 8, 4, 16, 8, True, True, 2),     # GQA + blocked slots
    (4, 1, 3, 8, 8, 16, 8, False, True, 8),    # isotropic performer
    (3, 2, 2, 8, 4, 16, 8, True, True, 2),     # n % block_b != 0
    (5, 2, 1, 4, 4, 8, 4, True, False, 3),     # stabilize off
    (6, 3, 4, 8, 4, 16, 8, False, True, 4),    # wider GQA fan-out
])
def test_fused_kernel_vs_oracle(b, g, hg, d, r, m, dv, dark, stab,
                                block_b):
    args = _fused_inputs(b, g, hg, d, r, m, dv, dark, seed=b * 7 + m)
    out = prf_fused_decode_fwd(*args, stabilize=stab, block_b=block_b,
                               interpret=True)
    exp = ref.prf_fused_decode_ref(*args, stabilize=stab)
    for o, e, name in zip(out, exp, ("out", "s", "z", "c")):
        np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                                   atol=1e-5, err_msg=name)


@settings(deadline=None, max_examples=12)
@given(st.integers(0, 10_000), st.integers(1, 5), st.integers(1, 3),
       st.integers(1, 4), st.booleans())
def test_fused_kernel_vs_oracle_hypothesis(seed, b, g, hg, dark):
    d, r, m, dv = 8, 4, 16, 8
    args = _fused_inputs(b, g, hg, d, r, m, dv, dark, seed=seed)
    out = prf_fused_decode_fwd(*args, block_b=2, interpret=True)
    exp = ref.prf_fused_decode_ref(*args)
    for o, e, name in zip(out, exp, ("out", "s", "z", "c")):
        np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                                   atol=1e-5, err_msg=name)


# ---------------------------------------------------------------------------
# fused path vs the jnp decode path (rf_attention_decode)
# ---------------------------------------------------------------------------

def _attn_setup(kind, b=3, g=2, hg=2, d=8, m=16, seed=0):
    cfg = fm.FeatureConfig(kind=kind, num_features=m, feature_rank=0)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    fparams = fm.init_feature_params(ks[0], cfg, d, n_groups=g)
    if kind == "darkformer":
        # a non-identity M so the data-aligned projection is exercised
        fparams["m_mat"] = fparams["m_mat"] + 0.1 * jax.random.normal(
            ks[1], fparams["m_mat"].shape)
    state = rfa.init_linear_serve_state(b, g, hg, m, d)
    proj = fm.precompose_projection(fparams, kind)
    return cfg, fparams, state, proj


@pytest.mark.parametrize("kind", ["darkformer", "performer", "lfk"])
@pytest.mark.parametrize("stabilize", [True, False])
def test_fused_decode_sequence_matches_jnp_path(kind, stabilize):
    """Token-by-token decode through the megakernel tracks the jnp path
    (atol 1e-5 f32) over a multi-step SEQUENCE: same online running-max
    stabilizer trajectory, same state advance, even though the fused
    path composes the projection as one x @ (W M)^T matmul."""
    b, g, hg, d, m = 3, 2, 2, 8, 16
    cfg, fparams, state, proj = _attn_setup(kind, b, g, hg, d, m)
    cfg = dataclasses.replace(cfg, stabilize=stabilize)
    state_f = state
    key = jax.random.PRNGKey(7)
    for t in range(6):
        kq, kk, kv, key = jax.random.split(key, 4)
        # large scale so new keys keep beating the running max and the
        # in-kernel rho-rescale actually fires
        q = 2.0 * jax.random.normal(kq, (b, g, hg, 1, d))
        k = 2.0 * jax.random.normal(kk, (b, g, 1, 1, d))
        v = jax.random.normal(kv, (b, g, 1, 1, d))
        out_j, state = rfa.rf_attention_decode(q, k, v, state, fparams,
                                               cfg)
        out_f, state_f = rfa.rf_attention_decode(q, k, v, state_f,
                                                 fparams, cfg,
                                                 use_kernel=True,
                                                 proj=proj)
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_j),
                                   atol=1e-5, err_msg=(kind, t))
        np.testing.assert_allclose(np.asarray(state_f.s),
                                   np.asarray(state.s), atol=1e-5,
                                   err_msg=(kind, t))
        np.testing.assert_allclose(np.asarray(state_f.z),
                                   np.asarray(state.z), atol=1e-5)
        np.testing.assert_allclose(np.asarray(state_f.c),
                                   np.asarray(state.c), atol=1e-5)


def test_fused_decode_sequence_matches_resumed_prefill():
    """Decoding T tokens one-by-one through the megakernel lands on the
    same (S, z, c) state and last output as the resumed-prefill
    reference over the same tokens (f32 tolerance — the whole-chunk
    prefill uses one max where decode walks a running max)."""
    b, g, hg, d, m, t = 2, 2, 2, 8, 16, 7
    cfg, fparams, state_f, proj = _attn_setup("darkformer", b, g, hg, d,
                                              m, seed=5)
    key = jax.random.PRNGKey(11)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, g, hg, t, d))
    k = jax.random.normal(kk, (b, g, 1, t, d))
    v = jax.random.normal(kv, (b, g, 1, t, d))

    out_f = None
    for i in range(t):
        out_f, state_f = rfa.rf_attention_decode(
            q[:, :, :, i:i + 1], k[:, :, :, i:i + 1], v[:, :, :, i:i + 1],
            state_f, fparams, cfg, use_kernel=True, proj=proj)
    out_p, state_p = rfa.rf_attention_prefill(q, k, v, fparams, cfg)
    np.testing.assert_allclose(np.asarray(out_f[:, :, :, 0]),
                               np.asarray(out_p[:, :, :, -1]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_f.s),
                               np.asarray(state_p.s), rtol=2e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(state_f.z),
                               np.asarray(state_p.z), rtol=2e-4,
                               atol=1e-5)


def test_fused_decode_from_fresh_state_sentinel():
    """The -1e30 fresh-state stabilizer sentinel passes through the
    in-kernel exp(c_old - c_new) rescale cleanly (rho underflows to 0
    against the all-zero state; out is finite)."""
    b, g, hg, d, m = 2, 1, 2, 8, 16
    cfg, fparams, state, proj = _attn_setup("darkformer", b, g, hg, d, m)
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(jax.random.fold_in(key, 0), (b, g, hg, 1, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, g, 1, 1, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, g, 1, 1, d))
    out, new = rfa.rf_attention_decode(q, k, v, state, fparams, cfg,
                                       use_kernel=True, proj=proj)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(jnp.isfinite(new.s)))
    ref_out, ref_new = rfa.rf_attention_decode(q, k, v, state, fparams,
                                               cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(new.c), np.asarray(ref_new.c),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# in-place aliasing
# ---------------------------------------------------------------------------

def test_fused_decode_aliases_pool_in_place():
    """The lowered pallas_call maps the (c, s, z) pool INPUTS onto the
    state OUTPUTS (input_output_aliases), so under jit with a donated
    pool no second pool-sized buffer is allocated — the property the
    megakernel exists for."""
    args = _fused_inputs(4, 2, 2, 8, 4, 16, 8, dark=True)
    q, k, v, a, m_mat, s, z, c = args

    def run(q, k, v, s, z, c):
        return ops.fused_prf_decode(q, k, v, a, m_mat, s, z, c)

    jaxpr = jax.make_jaxpr(run)(q, k, v, s, z, c)
    eqns = [e for e in jaxpr.jaxpr.eqns
            if "pallas" in str(e.primitive)]
    assert len(eqns) == 1, "decode must be ONE fused pallas_call"
    aliases = dict(eqns[0].params["input_output_aliases"])
    # inputs: q k v a m_mat c s z -> outputs: out s_new z_new c_new
    assert aliases == {5: 3, 6: 1, 7: 2}
    # and the wrapper must never pad the slot axis (a pad would copy
    # the pool): the iso variant drops m_mat, shifting the map by one
    jaxpr_iso = jax.make_jaxpr(
        lambda q, k, v, s, z, c: ops.fused_prf_decode(
            q, k, v, a, None, s, z, c))(q, k, v, s, z, c)
    eqns_iso = [e for e in jaxpr_iso.jaxpr.eqns
                if "pallas" in str(e.primitive)]
    assert dict(eqns_iso[0].params["input_output_aliases"]) == \
        {4: 3, 5: 1, 6: 2}


def test_fused_decode_block_divisor_never_pads():
    from repro.kernels.prf_fused_decode import _block_divisor
    for b in range(1, 33):
        for bb in (1, 2, 4, 8, 16):
            tb = _block_divisor(b, bb)
            assert b % tb == 0 and 1 <= tb <= max(1, min(bb, b))


# ---------------------------------------------------------------------------
# layer-stacked decode
# ---------------------------------------------------------------------------

def test_stacked_decode_matches_unit_layout_bitwise():
    """For the k=1 homogeneous patterns the stacked layout is the same
    leaves scanned the same way — logits must match BITWISE."""
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray([3, 7], jnp.int32)
    st_u = lm.init_serve_state(cfg, b=2, max_len=32)
    st_s = lm.init_serve_state(cfg, b=2, max_len=32, stacked=True)
    for _ in range(3):
        lg_u, st_u = lm.decode_step(params, cfg, toks, st_u)
        lg_s, st_s = lm.decode_step(params, cfg, toks, st_s)
        np.testing.assert_array_equal(np.asarray(lg_u), np.asarray(lg_s))
        toks = jnp.argmax(lg_u, -1).astype(jnp.int32)


def test_stacked_prefill_chunk_matches_unit_layout():
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray([[5, 9, 2, 7, 1]], jnp.int32)
    st_u = lm.init_serve_state(cfg, b=1, max_len=32, per_slot=True)
    st_s = lm.init_serve_state(cfg, b=1, max_len=32, per_slot=True,
                               stacked=True)
    lg_u, _ = lm.prefill_chunk(params, cfg, {"tokens": toks}, st_u)
    lg_s, _ = lm.prefill_chunk(params, cfg, {"tokens": toks}, st_s)
    np.testing.assert_array_equal(np.asarray(lg_u), np.asarray(lg_s))


def test_stacked_multiblock_homogeneous_pattern():
    """A k>1 homogeneous pattern interleaves b0/b1 params into one
    (n_layers,) stack; decode must match the unit layout to f32
    rounding (XLA may fuse the collapsed scan differently)."""
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg = dataclasses.replace(cfg, block_pattern=("attn", "attn"),
                              n_layers=4)
    assert lm.can_stack_layers(cfg) and cfg.n_units == 2
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    toks = jnp.asarray([4], jnp.int32)
    st_u = lm.init_serve_state(cfg, b=1, max_len=16)
    st_s = lm.init_serve_state(cfg, b=1, max_len=16, stacked=True)
    for _ in range(3):
        lg_u, st_u = lm.decode_step(params, cfg, toks, st_u)
        lg_s, st_s = lm.decode_step(params, cfg, toks, st_s)
        np.testing.assert_allclose(np.asarray(lg_u), np.asarray(lg_s),
                                   atol=1e-5)
        toks = jnp.argmax(lg_u, -1).astype(jnp.int32)


def test_stacked_refuses_heterogeneous_pattern():
    cfg = cfgs.get_config("recurrentgemma-2b", reduced=True)
    assert not lm.can_stack_layers(cfg)
    with pytest.raises(ValueError, match="homogeneous"):
        lm.init_serve_state(cfg, b=1, max_len=16, stacked=True)


@pytest.mark.parametrize("arch", ["rwkv6-7b", "recurrentgemma-2b"])
def test_engine_streams_match_reference_for_recurrent_archs(arch):
    """The engine's layout choice (stacked for rwkv's homogeneous
    pattern, per-unit for recurrentgemma) reproduces the single-
    sequence reference stream."""
    cfg = cfgs.get_config(arch, reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (9,), 0,
                                cfg.vocab).tolist()
    lg, st = lm.prefill(params, cfg, {"tokens": jnp.asarray([prompt])},
                        max_len=32)
    ref_toks = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(4):
        lg, st = lm.decode_step(params, cfg,
                                jnp.asarray(ref_toks[-1:]), st)
        ref_toks.append(int(jnp.argmax(lg[0])))

    from repro.serving import Request, ServingEngine
    eng = ServingEngine(params, cfg, max_slots=2, max_len=32)
    assert eng._stacked == (arch == "rwkv6-7b")
    uid = eng.submit(Request(prompt=prompt, max_new_tokens=5))
    got = {r.uid: r.tokens for r in eng.run()}
    assert got[uid] == ref_toks


def test_build_decode_proj_layouts():
    """build_decode_proj mirrors the serve-state layout, precomposing
    one (G, d, m) A per attention layer (None for non-PRF configs)."""
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg_k = dataclasses.replace(cfg, use_kernel=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    assert lm.build_decode_proj(params, cfg) is None      # no kernel
    proj = lm.build_decode_proj(params, cfg_k, stacked=True)
    w = params["units"]["b0"]["attn"]["feat"]["w"]
    n_layers, g, m, _ = w.shape
    d = cfg.head_dim
    assert proj["layers"]["a"].shape == (n_layers, g, d, m)
    proj_u = lm.build_decode_proj(params, cfg_k, stacked=False)
    assert proj_u["units"]["b0"]["a"].shape == (n_layers, g, d, m)
    cfg_ex = dataclasses.replace(cfgs.darkify(cfg, "exact"),
                                 use_kernel=True)
    assert lm.build_decode_proj(params, cfg_ex) is None   # no PRF state
