"""Core PRF math: unbiasedness, IS equivalence, Mahalanobis identities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (FeatureConfig, init_feature_params,
                        orthogonal_projection, gaussian_projection,
                        rf_attention, whitening_init)
from repro.core import variance as vr
from repro.core import attention as at


def test_lemma21_unbiased_mc():
    """Lemma 2.1: E[phi(q).phi(k)] = exp(q.k), checked by MC."""
    key = jax.random.PRNGKey(0)
    d, m = 8, 200_000
    kq, kk, kw = jax.random.split(key, 3)
    q = 0.4 * jax.random.normal(kq, (d,))
    k = 0.4 * jax.random.normal(kk, (d,))
    om = jax.random.normal(kw, (m, d))
    est = vr.mc_kernel_estimate(q, k, om)
    true = float(jnp.exp(q @ k))
    assert abs(float(est) - true) / true < 0.02


def test_eq3_dark_unbiased_mc():
    """Eq. 3: DARKFormer PRF is unbiased for exp(q^T Sigma k)."""
    key = jax.random.PRNGKey(1)
    d, r, m = 8, 8, 200_000
    kq, kk, km, kw = jax.random.split(key, 4)
    q = 0.4 * jax.random.normal(kq, (d,))
    k = 0.4 * jax.random.normal(kk, (d,))
    m_mat = 0.5 * jax.random.normal(km, (r, d))
    sigma = m_mat.T @ m_mat
    true = float(jnp.exp(q @ sigma @ k))
    # the positive-feature estimator is unbiased but heavy-tailed (exp
    # moments), so a single fixed draw can sit several percent off even
    # at m = 2e5; average independent projection draws before asserting.
    ests = []
    for s in range(4):
        w = jax.random.normal(jax.random.fold_in(kw, s), (m, r))
        omegas = w @ m_mat                 # omega = M^T w ~ N(0, Sigma)
        ests.append(float(vr.mc_dark_estimate(q, k, omegas, sigma)))
    est = sum(ests) / len(ests)
    assert abs(est - true) / true < 0.02


def test_prop41_importance_equivalence():
    """Prop 4.1: unweighted sampling from N(0,S) == weighted from N(0,I)."""
    key = jax.random.PRNGKey(2)
    d, m = 6, 400_000
    kq, kk, km, kw1, kw2 = jax.random.split(key, 5)
    q = 0.3 * jax.random.normal(kq, (d,))
    k = 0.3 * jax.random.normal(kk, (d,))
    # keep Sigma's spectrum in (0.5, ~1.2): the reweighted-from-isotropic
    # estimator has finite variance only for Sigma < 2I (the unweighted
    # DARKFormer estimator has no such restriction — that's the point).
    a = jax.random.normal(km, (d, d)) * 0.15
    sigma = a.T @ a + 0.5 * jnp.eye(d)
    chol = jnp.linalg.cholesky(sigma)
    om_sigma = jax.random.normal(kw1, (m, d)) @ chol.T
    est_unweighted = vr.mc_dark_estimate(q, k, om_sigma, sigma)
    om_iso = jax.random.normal(kw2, (m, d))
    w_is = vr.importance_weight(om_iso, sigma)
    zq = jnp.exp(om_iso @ q - 0.5 * q @ sigma @ q)
    zk = jnp.exp(om_iso @ k - 0.5 * k @ sigma @ k)
    est_weighted = jnp.mean(w_is * zq * zk)
    true = float(jnp.exp(q @ sigma @ k))
    assert abs(float(est_unweighted) - true) / true < 0.05
    assert abs(float(est_weighted) - true) / true < 0.05


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10_000), st.integers(2, 12))
def test_mahalanobis_identity(seed, d):
    """App. C: q^T Sigma k == (Mq).(Mk) and ||q-k||_Sigma == ||Mq-Mk||."""
    key = jax.random.PRNGKey(seed)
    kq, kk, km = jax.random.split(key, 3)
    q = jax.random.normal(kq, (d,))
    k = jax.random.normal(kk, (d,))
    m_mat = jax.random.normal(km, (d, d))
    sigma = m_mat.T @ m_mat
    lhs = q @ sigma @ k
    rhs = (m_mat @ q) @ (m_mat @ k)
    np.testing.assert_allclose(lhs, rhs, rtol=2e-4)
    dist_s = (q - k) @ sigma @ (q - k)
    dist_m = jnp.sum(jnp.square(m_mat @ (q - k)))
    np.testing.assert_allclose(dist_s, dist_m, rtol=2e-4)


def test_whitening_init_whitens():
    """Prop C.1: M = Lam^{-1/2} makes Cov(Mx) = I."""
    key = jax.random.PRNGKey(3)
    d = 8
    a = jax.random.normal(key, (d, d))
    lam = a @ a.T / d + 0.1 * jnp.eye(d)
    m = whitening_init(lam)
    white = m @ lam @ m.T
    np.testing.assert_allclose(np.asarray(white), np.eye(d), atol=1e-3)


def test_orthogonal_projection_blocks_orthogonal():
    w = orthogonal_projection(jax.random.PRNGKey(0), 16, 16)
    # rows within the block are orthogonal (scaled)
    gram = np.asarray(w @ w.T)
    off = gram - np.diag(np.diag(gram))
    assert np.abs(off).max() < 1e-3


def test_orthogonal_projection_marginal_norms():
    """Row norms follow chi(d): mean ~ sqrt(d)."""
    w = orthogonal_projection(jax.random.PRNGKey(1), 512, 64)
    norms = np.linalg.norm(np.asarray(w), axis=1)
    assert abs(norms.mean() - np.sqrt(64)) < 0.5


def test_dark_equals_performer_at_identity():
    key = jax.random.PRNGKey(4)
    B, G, Hg, L, d = 2, 2, 2, 16, 8
    kq, kk, kv, kp = jax.random.split(key, 4)
    q = jax.random.normal(kq, (B, G, Hg, L, d)) * 0.5
    k = jax.random.normal(kk, (B, G, 1, L, d)) * 0.5
    v = jax.random.normal(kv, (B, G, 1, L, d))
    cfg_p = FeatureConfig(kind="performer", num_features=64)
    cfg_d = FeatureConfig(kind="darkformer", num_features=64)
    fp = init_feature_params(kp, cfg_p, d, n_groups=G)
    fd = init_feature_params(kp, cfg_d, d, n_groups=G)  # m_mat = I
    out_p = rf_attention(q, k, v, fp, cfg_p)
    out_d = rf_attention(q, k, v, fd, cfg_d)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                               atol=1e-6)


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 1000))
def test_attention_rows_are_convex_combination(seed):
    """PRF attention outputs lie in the convex hull of V rows (positive
    features -> positive weights summing to 1, up to eps)."""
    key = jax.random.PRNGKey(seed)
    B, G, Hg, L, d = 1, 1, 1, 12, 4
    kq, kk, kp = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, G, Hg, L, d)) * 0.5
    k = jax.random.normal(kk, (B, G, 1, L, d)) * 0.5
    v = jnp.ones((B, G, 1, L, d))
    cfg = FeatureConfig(kind="darkformer", num_features=32)
    fp = init_feature_params(kp, cfg, d, n_groups=G)
    out = rf_attention(q, k, v, fp, cfg)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-3)


def test_stabilizer_invariance():
    """Attention output must not depend on the stabilizer (it cancels)."""
    key = jax.random.PRNGKey(5)
    B, G, Hg, L, d = 2, 1, 2, 16, 8
    kq, kk, kv, kp = jax.random.split(key, 4)
    q = jax.random.normal(kq, (B, G, Hg, L, d))
    k = jax.random.normal(kk, (B, G, 1, L, d))
    v = jax.random.normal(kv, (B, G, 1, L, d))
    cfg_on = FeatureConfig(kind="darkformer", num_features=64,
                           stabilize=True, eps=0.0)
    cfg_off = FeatureConfig(kind="darkformer", num_features=64,
                            stabilize=False, eps=0.0)
    fp = init_feature_params(kp, cfg_on, d, n_groups=G)
    out_on = rf_attention(q, k, v, fp, cfg_on)
    out_off = rf_attention(q, k, v, fp, cfg_off)
    np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_off),
                               atol=2e-4)


def test_approx_error_decreases_with_m():
    key = jax.random.PRNGKey(6)
    B, G, Hg, L, d = 2, 1, 2, 32, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, G, Hg, L, d)) * 0.5
    k = jax.random.normal(kk, (B, G, 1, L, d)) * 0.5
    v = jax.random.normal(kv, (B, G, 1, L, d))
    exact = rf_attention(q, k, v, None, FeatureConfig(kind="exact"))
    errs = []
    for m in (16, 128, 1024):
        cfg = FeatureConfig(kind="performer", num_features=m)
        fp = init_feature_params(jax.random.PRNGKey(7), cfg, d, n_groups=G)
        out = rf_attention(q, k, v, fp, cfg)
        errs.append(float(jnp.mean(jnp.abs(out - exact))))
    assert errs[0] > errs[1] > errs[2]


def test_decode_matches_prefill_then_full():
    key = jax.random.PRNGKey(8)
    B, G, Hg, L, d = 2, 2, 2, 24, 8
    kq, kk, kv, kp = jax.random.split(key, 4)
    q = jax.random.normal(kq, (B, G, Hg, L, d)) * 0.5
    k = jax.random.normal(kk, (B, G, 1, L, d)) * 0.5
    v = jax.random.normal(kv, (B, G, 1, L, d))
    cfg = FeatureConfig(kind="darkformer", num_features=64)
    fp = init_feature_params(kp, cfg, d, n_groups=G)
    full = rf_attention(q, k, v, fp, cfg)
    half = L // 2
    _, st = at.rf_attention_prefill(q[:, :, :, :half], k[:, :, :, :half],
                                    v[:, :, :, :half], fp, cfg)
    for t in range(half, L):
        o, st = at.rf_attention_decode(q[:, :, :, t:t + 1],
                                       k[:, :, :, t:t + 1],
                                       v[:, :, :, t:t + 1], st, fp, cfg)
        np.testing.assert_allclose(np.asarray(o[:, :, :, 0]),
                                   np.asarray(full[:, :, :, t]), atol=5e-3)


def test_exact_decode_bitwise():
    key = jax.random.PRNGKey(9)
    B, G, Hg, L, d = 1, 2, 2, 16, 8
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, G, Hg, L, d))
    k = jax.random.normal(kk, (B, G, 1, L, d))
    v = jax.random.normal(kv, (B, G, 1, L, d))
    cfg = FeatureConfig(kind="exact")
    full = rf_attention(q, k, v, None, cfg)
    half = L // 2
    _, st = at.rf_attention_prefill(q[:, :, :, :half], k[:, :, :, :half],
                                    v[:, :, :, :half], None, cfg,
                                    max_len=L)
    for t in range(half, L):
        o, st = at.rf_attention_decode(q[:, :, :, t:t + 1],
                                       k[:, :, :, t:t + 1],
                                       v[:, :, :, t:t + 1], st, None, cfg)
        np.testing.assert_allclose(np.asarray(o[:, :, :, 0]),
                                   np.asarray(full[:, :, :, t]), atol=1e-5)
