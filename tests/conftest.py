"""Shared test config. NOTE: no xla_force_host_platform_device_count here —
smoke tests and benches must see 1 device; multi-device tests spawn
subprocesses with their own XLA_FLAGS (see test_distributed.py)."""
import jax
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: benchmarks-adjacent / subprocess-heavy tests skipped by "
        "scripts/check.sh --fast")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
