"""Shared test config. NOTE: no xla_force_host_platform_device_count here —
smoke tests and benches must see 1 device; multi-device tests spawn
subprocesses with their own XLA_FLAGS (see test_distributed.py)."""
import gc

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_executables():
    """Release XLA executables between test modules.

    Every jitted (shapes × static-args) combination keeps its compiled
    executable alive in the owning function's cache, and each executable
    holds several mmap'd JIT code regions. Across the full suite that
    monotonically approaches vm.max_map_count (65530 by default), at
    which point LLVM's code emitter dies with SIGSEGV mid-compile.
    Clearing per module bounds the map count at the largest single
    module's working set."""
    yield
    jax.clear_caches()
    gc.collect()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: benchmarks-adjacent / subprocess-heavy tests skipped by "
        "scripts/check.sh --fast")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
