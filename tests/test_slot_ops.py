"""Property tests for the slot-layout module (repro/serving/slots.py).

The pool primitives are pure pytree surgery, so their contracts are
crisp and hypothesis-checkable across the heterogeneous serve-state
layouts (stacked scanned units at slot axis 1, remainder layers and
``pos`` at axis 0, PRF vs exact-cache vs RWKV state leaves):

  * ``write_slots`` then ``read_slots`` at the same indices is the
    identity on the written rows, and a no-op on every other row;
  * the multi-index forms agree with the single-slot dynamic-slice
    forms;
  * ``freeze_inactive`` keeps exactly the inactive rows, and its
    static ``all_active`` fast path is bit-identical to the masked
    select when every row is live.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import configs as cfgs
from repro.models import lm
from repro.serving import slots as slot_ops

ARCHS = {
    "darkformer": lambda: cfgs.darkify(
        cfgs.get_config("smollm-135m", reduced=True), "darkformer"),
    "exact": lambda: cfgs.darkify(
        cfgs.get_config("smollm-135m", reduced=True), "exact"),
    "rwkv": lambda: cfgs.get_config("rwkv6-7b", reduced=True),
}
N_SLOTS = 4


def _pool(kind, seed=0, b=N_SLOTS):
    """A slot pool with distinguishable random contents per row."""
    cfg = ARCHS[kind]()
    pool = lm.init_serve_state(cfg, b=b, max_len=16, per_slot=True)
    leaves, treedef = jax.tree_util.tree_flatten(pool)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, leaf in enumerate(leaves):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(jax.random.normal(jax.random.fold_in(key, i),
                                         leaf.shape, leaf.dtype))
        else:
            out.append(jax.random.randint(jax.random.fold_in(key, i),
                                          leaf.shape, 0, 13
                                          ).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def _rows_equal(tree_a, tree_b, row_a, row_b):
    """Assert slot row_a of tree_a == slot row_b of tree_b, every leaf."""
    fa = jax.tree_util.tree_flatten_with_path(tree_a)[0]
    fb = jax.tree_util.tree_flatten_with_path(tree_b)[0]
    for (pa, a), (_, b) in zip(fa, fb):
        axis = 1 if "units" in jax.tree_util.keystr(pa) else 0
        np.testing.assert_array_equal(
            np.take(np.asarray(a), row_a, axis=axis),
            np.take(np.asarray(b), row_b, axis=axis),
            err_msg=jax.tree_util.keystr(pa))


@pytest.mark.parametrize("kind", sorted(ARCHS))
@given(seed=st.integers(0, 10_000), data=st.data())
@settings(max_examples=10, deadline=None)
def test_write_read_slots_roundtrip(kind, seed, data):
    """Scatter P distinct rows from one pool into another, gather them
    back: written rows round-trip exactly, untouched rows stay frozen."""
    perm = list(np.random.RandomState(seed).permutation(N_SLOTS))
    p = data.draw(st.integers(1, N_SLOTS))
    idx = jnp.asarray(perm[:p], jnp.int32)
    dst = _pool(kind, seed=1)
    src = _pool(kind, seed=2)
    rows = slot_ops.read_slots(src, idx)
    out = slot_ops.write_slots(dst, rows, idx)
    back = slot_ops.read_slots(out, idx)
    for r in range(p):
        _rows_equal(back, src, r, int(idx[r]))          # round-trip
        _rows_equal(out, src, int(idx[r]), int(idx[r]))
    for other in set(range(N_SLOTS)) - set(int(i) for i in idx):
        _rows_equal(out, dst, other, other)             # untouched


@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_multi_index_agrees_with_single_slot_forms(kind):
    """write_slots/read_slots at one index == write_slot/read_slot."""
    pool = _pool(kind, seed=3)
    src = _pool(kind, seed=4)
    one = slot_ops.read_slots(src, jnp.asarray([2], jnp.int32))
    a = slot_ops.write_slots(pool, one, jnp.asarray([1], jnp.int32))
    b = slot_ops.write_slot(pool, slot_ops.read_slot(src, jnp.int32(2)),
                            jnp.int32(1))
    for (pa, x), (_, y) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=jax.tree_util.keystr(pa))


@pytest.mark.parametrize("kind", sorted(ARCHS))
@given(mask_bits=st.integers(0, 2 ** N_SLOTS - 1))
@settings(max_examples=12, deadline=None)
def test_freeze_inactive_masks_exactly(kind, mask_bits):
    """Active rows take the new pool, inactive rows keep the old —
    row-exact across every leaf layout."""
    old = _pool(kind, seed=5)
    new = _pool(kind, seed=6)
    active = np.array([(mask_bits >> i) & 1 == 1 for i in range(N_SLOTS)])
    out = slot_ops.freeze_inactive(old, new, jnp.asarray(active))
    for i in range(N_SLOTS):
        _rows_equal(out, new if active[i] else old, i, i)


@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_freeze_all_active_fast_path_is_identity(kind):
    """The static all_active fast path must be bit-identical to the
    masked select with an all-True mask (it skips the select)."""
    old = _pool(kind, seed=7)
    new = _pool(kind, seed=8)
    ones = jnp.ones((N_SLOTS,), bool)
    masked = slot_ops.freeze_inactive(old, new, ones)
    fast = slot_ops.freeze_inactive(old, new, ones, all_active=True)
    for (pa, x), (_, y) in zip(
            jax.tree_util.tree_flatten_with_path(masked)[0],
            jax.tree_util.tree_flatten_with_path(fast)[0]):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=jax.tree_util.keystr(pa))
