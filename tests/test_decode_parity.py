"""Decode/prefill parity (ISSUE satellite): token-by-token decode —
including slot eviction and re-admission mid-stream — must agree with a
single prefill pass over the same tokens.

Two layers of guarantee:
  * logits: stepwise decode tracks the full causal pass to f32 rounding
    (the decode path swaps the whole-prompt k-stabilizer max for a
    running max; exact in infinite precision, ~1e-5 in f32);
  * tokens: greedy streams through the serving engine are identical even
    when the sequence is evicted mid-stream and re-admitted via a fresh
    prefill over its own history.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import lm
from repro.serving import Request, ServingEngine


def _setup(kind):
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg = cfgs.darkify(cfg, kind, cfg.attn.num_features)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("kind", ["darkformer", "performer"])
def test_stepwise_decode_tracks_full_pass(kind):
    """decode_step over positions p..L-1 == forward_train logits there."""
    cfg, params = _setup(kind)
    L, prefix = 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, L), 0, cfg.vocab)
    full, _ = lm.forward_train(params, cfg, {"tokens": toks,
                                             "labels": toks})
    _, st = lm.prefill(params, cfg, {"tokens": toks[:, :prefix]},
                       max_len=L + 4)
    maxerr = 0.0
    for t in range(prefix, L):
        lg, st = lm.decode_step(params, cfg, toks[:, t], st)
        maxerr = max(maxerr, float(jnp.abs(lg - full[:, t]).max()))
    assert maxerr < 1e-3, (kind, maxerr)


@pytest.mark.parametrize("kind", ["darkformer", "performer"])
def test_evict_readmit_matches_uninterrupted_decode(kind):
    """Generate k tokens, evict the slot, re-admit with prompt+history
    (fresh prefill into a different slot), finish — the combined greedy
    stream equals one uninterrupted decode."""
    cfg, params = _setup(kind)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (8,), 0,
                                cfg.vocab).tolist()
    n_total = 10

    # uninterrupted reference
    lg, st = lm.prefill(params, cfg, {"tokens": jnp.asarray([prompt])},
                        max_len=48)
    ref = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(n_total - 1):
        lg, st = lm.decode_step(params, cfg, jnp.asarray(ref[-1:]), st)
        ref.append(int(jnp.argmax(lg[0])))

    # engine: decode a while, evict mid-stream, re-admit with history
    eng = ServingEngine(params, cfg, max_slots=2, max_len=48)
    # occupy slot 0 so the re-admitted request lands in a fresh slot
    eng.submit(Request(prompt=prompt[:5], max_new_tokens=n_total + 6))
    uid = eng.submit(Request(prompt=prompt, max_new_tokens=n_total))
    for _ in range(4):
        eng.step()
    part = eng.cancel(uid)
    assert part.cancelled and 0 < len(part.tokens) < n_total
    assert part.tokens == ref[:len(part.tokens)]

    uid2 = eng.submit(Request(prompt=prompt + part.tokens,
                              max_new_tokens=n_total - len(part.tokens)))
    rest = {r.uid: r.tokens for r in eng.run()}[uid2]
    assert part.tokens + rest == ref, kind
