"""Fused data-aligned PRF prefill megakernel (ISSUE 5 tentpole).

Five layers of guarantee, all in interpret mode on CPU:

  * kernel vs oracle: ``prf_fused_prefill_fwd`` == ``ref.prf_fused_
    prefill_ref`` across kinds, GQA geometries, ragged valid_len rows
    (incl. a pure-padding valid_len=0 row and a row ending mid-chunk),
    stabilize=False, and multi-chunk internal scans (where the oracle
    is chained per-sub-chunk — the kernel's stabilizer trajectory);
  * kernel vs the jnp prefill path: the fused one-call chunk equals
    ``rf_attention_prefill(use_kernel=False)`` to f32 rounding over a
    SEQUENCE of resumed ragged chunks — the running-stabilizer
    contract — and a fused CHUNKED stream reproduces the one-shot jnp
    ``lm.prefill`` greedy stream;
  * aliasing: the pallas_call carries ``input_output_aliases`` mapping
    the (c, s, z) state inputs onto the state outputs, so a donated
    pool is updated in place;
  * one pallas_call per layer per packed chunk: the jaxpr of a fused
    ``lm.prefill_chunk`` contains exactly ONE pallas primitive (inside
    the scanned layer body);
  * engine: ragged batched admission under ``use_kernel`` streams
    identically to the jnp engine, and ``stats`` reports which path
    compiled.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs as cfgs
from repro.core import attention as rfa
from repro.core import feature_maps as fm
from repro.kernels import ops, ref
from repro.kernels.prf_fused_prefill import prf_fused_prefill_fwd
from repro.models import lm


def _fused_inputs(b, g, hg, d, r, m, dv, l, dark, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    q = jax.random.normal(ks[0], (b, g, hg, l, d))
    k = jax.random.normal(ks[1], (b, g, l, d))
    v = jax.random.normal(ks[2], (b, g, l, dv))
    m_mat = 0.4 * jax.random.normal(ks[3], (g, r, d)) if dark else None
    w = jax.random.normal(ks[4], (g, m, r if dark else d))
    a = (jnp.einsum("gmr,grd->gdm", w, m_mat) if dark
         else jnp.swapaxes(w, -1, -2))
    s = jax.random.normal(ks[5], (b, g, hg, m, dv))
    z = jax.random.uniform(ks[6], (b, g, hg, m)) + 0.5
    c = jax.random.normal(ks[7], (b, g)) + 1.0
    return q, k, v, a, m_mat, s, z, c


def _chained_oracle(q, k, v, a, m_mat, s, z, c, valid_len, t, stabilize):
    """Per-sub-chunk oracle chain: the kernel advances its running-max
    stabilizer once per internal T-chunk, so the ground truth for a
    multi-chunk call is the jnp oracle resumed T tokens at a time."""
    l = q.shape[3]
    outs = []
    for st_ in range(0, l, t):
        en = min(st_ + t, l)
        vls = (None if valid_len is None
               else jnp.clip(valid_len - st_, 0, en - st_))
        o, s, z, c = ref.prf_fused_prefill_ref(
            q[:, :, :, st_:en], k[:, :, st_:en], v[:, :, st_:en],
            a, m_mat, s, z, c, vls, stabilize=stabilize)
        outs.append(o)
    return jnp.concatenate(outs, axis=3), s, z, c


def _assert_close(out, exp, l, valid_len, msg):
    for o, e, name in zip(out, exp, ("out", "s", "z", "c")):
        o = np.asarray(o, np.float32)
        e = np.asarray(e, np.float32)
        if name == "out" and valid_len is not None:
            # outputs at masked positions are garbage by contract
            mask = (np.arange(l)[None] < np.asarray(valid_len)[:, None]
                    )[:, None, None, :, None]
            o = np.where(mask, o, 0.0)
            e = np.where(mask, e, 0.0)
        np.testing.assert_allclose(o, e, atol=2e-5, rtol=2e-4,
                                   err_msg=(name, msg))


@pytest.mark.parametrize(
    "b,g,hg,d,r,m,dv,l,dark,stab,chunk,block_b,valid_len", [
        (1, 1, 1, 4, 2, 8, 4, 5, True, True, 8, 1, None),
        (3, 2, 2, 8, 4, 16, 8, 12, True, True, 16, 2, None),   # GQA
        (4, 1, 3, 8, 8, 16, 8, 7, False, True, 4, 8, None),    # iso, 2-chunk
        (2, 2, 2, 8, 4, 16, 8, 9, True, False, 4, 1, None),    # no stab
        (4, 2, 2, 8, 4, 16, 8, 10, True, True, 4, 4, (0, 3, 10, 7)),
        (3, 1, 2, 8, 4, 16, 8, 11, True, True, 16, 3, (11, 5, 0)),
        (5, 2, 1, 4, 4, 8, 4, 6, True, False, 8, 3, (6, 0, 2, 5, 1)),
        (6, 3, 4, 8, 4, 16, 8, 8, False, True, 8, 4, (8, 1, 7, 0, 4, 8)),
    ])
def test_fused_prefill_kernel_vs_oracle(b, g, hg, d, r, m, dv, l, dark,
                                        stab, chunk, block_b, valid_len):
    args = _fused_inputs(b, g, hg, d, r, m, dv, l, dark, seed=b * 7 + l)
    vl = (None if valid_len is None
          else jnp.asarray(valid_len, jnp.int32))
    out = prf_fused_prefill_fwd(*args, vl, stabilize=stab, chunk=chunk,
                                block_b=block_b, interpret=True)
    exp = _chained_oracle(*args, vl, min(chunk, l), stab)
    _assert_close(out, exp, l, valid_len, (b, g, hg, l, chunk))


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 3),
       st.integers(1, 3), st.integers(1, 10), st.booleans(),
       st.booleans())
def test_fused_prefill_kernel_vs_oracle_hypothesis(seed, b, g, hg, l,
                                                   dark, ragged):
    d, r, m, dv = 8, 4, 16, 8
    args = _fused_inputs(b, g, hg, d, r, m, dv, l, dark, seed=seed)
    vl = None
    if ragged:
        vl = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0,
                                l + 1)
    out = prf_fused_prefill_fwd(*args, vl, chunk=4, block_b=2,
                                interpret=True)
    exp = _chained_oracle(*args, vl, min(4, l), True)
    _assert_close(out, exp, l, vl, (seed, b, g, hg, l))


# ---------------------------------------------------------------------------
# fused path vs the jnp prefill path (rf_attention_prefill)
# ---------------------------------------------------------------------------

def _attn_setup(kind, b, g, hg, d, m, seed=0):
    cfg = fm.FeatureConfig(kind=kind, num_features=m, feature_rank=0)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    fparams = fm.init_feature_params(ks[0], cfg, d, n_groups=g)
    if kind == "darkformer":
        fparams["m_mat"] = fparams["m_mat"] + 0.1 * jax.random.normal(
            ks[1], fparams["m_mat"].shape)
    state = rfa.init_linear_serve_state(b, g, hg, m, d)
    proj = fm.precompose_projection(fparams, kind)
    return cfg, fparams, state, proj


@pytest.mark.parametrize("kind", ["darkformer", "performer", "lfk"])
@pytest.mark.parametrize("stabilize", [True, False])
def test_fused_prefill_chunk_sequence_matches_jnp_path(kind, stabilize):
    """Chunk-by-chunk resumed prefill through the megakernel tracks the
    jnp path (f32 tolerance) over a multi-chunk SEQUENCE with ragged
    rows: same running-max stabilizer trajectory, same masked state
    advance, even though the fused path composes the projection as one
    x @ (W M)^T matmul."""
    b, g, hg, d, m, l = 3, 2, 2, 8, 16, 6
    cfg, fparams, state, proj = _attn_setup(kind, b, g, hg, d, m)
    cfg = dataclasses.replace(cfg, stabilize=stabilize)
    state_f = state
    key = jax.random.PRNGKey(7)
    vls = [None, jnp.asarray([6, 3, 0]), jnp.asarray([2, 6, 5]), None]
    for t, vl in enumerate(vls):
        kq, kk, kv, key = jax.random.split(key, 4)
        # large scale so new keys keep beating the running max and the
        # in-kernel rho-rescale actually fires
        q = 2.0 * jax.random.normal(kq, (b, g, hg, l, d))
        k = 2.0 * jax.random.normal(kk, (b, g, 1, l, d))
        v = jax.random.normal(kv, (b, g, 1, l, d))
        out_j, state = rfa.rf_attention_prefill(q, k, v, fparams, cfg,
                                                state=state, valid_len=vl)
        out_f, state_f = rfa.rf_attention_prefill(q, k, v, fparams, cfg,
                                                  state=state_f,
                                                  valid_len=vl,
                                                  use_kernel=True,
                                                  proj=proj)
        of, oj = np.asarray(out_f), np.asarray(out_j)
        if vl is not None:
            mask = (np.arange(l)[None] < np.asarray(vl)[:, None]
                    )[:, None, None, :, None]
            of = np.where(mask, of, 0.0)
            oj = np.where(mask, oj, 0.0)
        np.testing.assert_allclose(of, oj, atol=1e-4, err_msg=(kind, t))
        for name in ("s", "z", "c"):
            np.testing.assert_allclose(
                np.asarray(getattr(state_f, name)),
                np.asarray(getattr(state, name)), atol=1e-4,
                err_msg=(kind, t, name))


def test_fused_prefill_row_ending_mid_chunk_leaves_no_trace():
    """A ragged row whose valid length ends inside the kernel's internal
    T-chunk advances its state exactly as the same row prefixed alone
    (B=1, unpadded) — the padding contract at sub-chunk granularity."""
    b, g, hg, d, m, l = 3, 1, 2, 8, 16, 10
    cfg, fparams, state, proj = _attn_setup("darkformer", b, g, hg, d, m,
                                            seed=3)
    key = jax.random.PRNGKey(5)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, g, hg, l, d))
    k = jax.random.normal(kk, (b, g, 1, l, d))
    v = jax.random.normal(kv, (b, g, 1, l, d))
    vl = jnp.asarray([10, 6, 0], jnp.int32)   # row 1 ends mid-chunk (T=4)
    _, st_batch = rfa.rf_attention_prefill(
        q, k, v, fparams, cfg, state=state, valid_len=vl,
        use_kernel=True, proj=proj, chunk=4)
    for row in range(b):
        lr = int(vl[row])
        st1 = rfa.init_linear_serve_state(1, g, hg, m, d)
        if lr > 0:
            _, st1 = rfa.rf_attention_prefill(
                q[row:row + 1, :, :, :lr], k[row:row + 1, :, :, :lr],
                v[row:row + 1, :, :, :lr], fparams, cfg, state=st1,
                use_kernel=True, proj=proj, chunk=4)
        for name in ("s", "z", "c"):
            np.testing.assert_allclose(
                np.asarray(getattr(st_batch, name)[row:row + 1]),
                np.asarray(getattr(st1, name)), atol=1e-5,
                err_msg=(row, name))


def test_fused_chunked_stream_matches_one_shot_jnp_prefill():
    """Multi-chunk resume parity at the lm level: feeding a prompt
    through the fused kernel in resumed chunks reproduces the one-shot
    jnp ``lm.prefill`` — greedy next token identical, every state leaf
    f32-close (the stabilizer trajectory differs, so bitwise equality
    is out of scope by the docs/kernels.md §3 contract)."""
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg_k = dataclasses.replace(cfg, use_kernel=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (13,), 0,
                                cfg.vocab)
    lg_ref, st_ref = lm.prefill(params, cfg,
                                {"tokens": prompt[None]}, max_len=32)
    st = lm.init_serve_state(cfg, b=1, max_len=32, per_slot=True,
                             stacked=True)
    lg = None
    for start in (0, 5, 10):
        end = min(start + 5, 13)
        lg, st = lm.prefill_chunk(params, cfg_k,
                                  {"tokens": prompt[None, start:end]}, st)
    assert int(jnp.argmax(lg[0])) == int(jnp.argmax(lg_ref[0, -1]))
    np.testing.assert_allclose(np.asarray(lg[0]),
                               np.asarray(lg_ref[0, -1]), atol=1e-3)
    # the assembled state must CONTINUE the sequence like the reference:
    # greedy decode streams from both states agree
    toks_f = [int(jnp.argmax(lg[0]))]
    toks_r = [int(jnp.argmax(lg_ref[0, -1]))]
    st_r = st_ref
    for _ in range(4):
        lg, st = lm.decode_step(params, cfg_k,
                                jnp.asarray(toks_f[-1:]), st)
        toks_f.append(int(jnp.argmax(lg[0])))
        lg_r, st_r = lm.decode_step(params, cfg,
                                    jnp.asarray(toks_r[-1:]), st_r)
        toks_r.append(int(jnp.argmax(lg_r[0])))
    assert toks_f == toks_r


# ---------------------------------------------------------------------------
# in-place aliasing + one-call-per-layer
# ---------------------------------------------------------------------------

def test_fused_prefill_aliases_state_in_place():
    """The lowered pallas_call maps the (c, s, z) state INPUTS onto the
    state OUTPUTS (input_output_aliases), so under jit with a donated
    staging pool no second pool-sized buffer is allocated."""
    q, k, v, a, m_mat, s, z, c = _fused_inputs(4, 2, 2, 8, 4, 16, 8, 6,
                                               dark=True)
    vl = jnp.asarray([6, 3, 6, 0], jnp.int32)

    def run(q, k, v, s, z, c):
        return ops.fused_prf_prefill(q, k, v, a, m_mat, s, z, c, vl)

    jaxpr = jax.make_jaxpr(run)(q, k, v, s, z, c)
    eqns = [e for e in jaxpr.jaxpr.eqns if "pallas" in str(e.primitive)]
    assert len(eqns) == 1, "prefill must be ONE fused pallas_call"
    aliases = dict(eqns[0].params["input_output_aliases"])
    # inputs: q k v a m_mat vl c s z -> outputs: out s_new z_new c_new
    assert aliases == {6: 3, 7: 1, 8: 2}
    # the iso variant drops m_mat, shifting the map by one
    jaxpr_iso = jax.make_jaxpr(
        lambda q, k, v, s, z, c: ops.fused_prf_prefill(
            q, k, v, a, None, s, z, c, vl))(q, k, v, s, z, c)
    eqns_iso = [e for e in jaxpr_iso.jaxpr.eqns
                if "pallas" in str(e.primitive)]
    assert dict(eqns_iso[0].params["input_output_aliases"]) == \
        {5: 3, 6: 1, 7: 2}


def _count_pallas(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if "pallas" in str(eqn.primitive):
            n += 1
        for val in eqn.params.values():
            sub = getattr(val, "jaxpr", None)
            if sub is not None:
                n += _count_pallas(sub)
            elif isinstance(val, (list, tuple)):
                for v_ in val:
                    sub = getattr(v_, "jaxpr", None)
                    if sub is not None:
                        n += _count_pallas(sub)
    return n


def test_fused_prefill_is_one_pallas_call_per_layer_per_chunk():
    """The fused lm-level chunk lowers to exactly ONE pallas primitive —
    sitting inside the scanned layer body, i.e. one kernel dispatch per
    layer per packed chunk (the ISSUE 5 acceptance bar). The two-stage
    path also carries one (the carry scan), so the fused path must not
    regress the count while absorbing the whole featmap stage."""
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg_k = dataclasses.replace(cfg, use_kernel=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    st = lm.init_serve_state(cfg, b=2, max_len=32, per_slot=True,
                             stacked=True)
    toks = jnp.zeros((2, 8), jnp.int32)
    vl = jnp.asarray([8, 5], jnp.int32)
    proj = lm.build_decode_proj(params, cfg_k, stacked=True)
    jaxpr = jax.make_jaxpr(
        lambda p, s, t, v: lm.prefill_chunk(p, cfg_k, {"tokens": t}, s,
                                            valid_len=v, proj=proj))(
        params, st, toks, vl)
    assert _count_pallas(jaxpr.jaxpr) == 1
    # and the jnp reference path has none
    jaxpr_j = jax.make_jaxpr(
        lambda p, s, t, v: lm.prefill_chunk(p, cfg, {"tokens": t}, s,
                                            valid_len=v))(
        params, st, toks, vl)
    assert _count_pallas(jaxpr_j.jaxpr) == 0


# ---------------------------------------------------------------------------
# engine: ragged batched admission through the fused path
# ---------------------------------------------------------------------------

def test_engine_ragged_admission_runs_fused_path_and_matches_jnp():
    """A burst of ragged admissions under chunked prefill, decoded
    through the fused kernels, streams identically to the jnp engine —
    and the engine reports the path it compiled."""
    from repro.serving import Request, ServingEngine
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [jax.random.randint(jax.random.PRNGKey(10 + i),
                                  (n,), 0, cfg.vocab).tolist()
               for i, n in enumerate((11, 5, 9, 2))]
    streams = {}
    paths = {}
    for use_kernel in (False, True):
        c = dataclasses.replace(cfg, use_kernel=use_kernel)
        eng = ServingEngine(params, c, max_slots=3, max_len=48,
                            chunk_tokens=8)
        uids = [eng.submit(Request(prompt=p, max_new_tokens=n))
                for p, n in zip(prompts, (5, 4, 6, 3))]
        got = {r.uid: r.tokens for r in eng.run()}
        streams[use_kernel] = [got[u] for u in uids]
        paths[use_kernel] = (eng.stats["prefill_path"],
                             eng.stats["decode_path"])
    assert streams[False] == streams[True]
    assert paths[False] == ("jnp", "jnp")
    assert paths[True] == ("fused_kernel", "fused_kernel")


def test_engine_stats_report_exact_path():
    from repro.serving import ServingEngine
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cfg_ex = dataclasses.replace(cfgs.darkify(cfg, "exact"),
                                 use_kernel=True)
    eng = ServingEngine(params, cfg_ex, max_slots=2, max_len=32)
    assert eng.stats["prefill_path"] == "exact"
    assert eng.stats["decode_path"] == "exact"
