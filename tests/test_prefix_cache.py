"""Prefix cache + O(1) state forking (ISSUE 10 tentpole).

The cache makes fork-on-admit a pure scheduling optimization: a forked
request resumes chunked prefill from the cached cursor on the same
chunk grid a cold start would use, so its token stream must be
BITWISE-identical to an engine that never cached anything. These tests
pin that contract and the store's mechanics:

  * fork parity — greedy and sampled storms of prefix-sharing requests
    through a cache-on engine vs a cache-less reference, both
    schedulers, PRF kind (snapshot fork) AND exact kind (paged KV,
    copy-on-write page-table fork);
  * the store itself — longest-match + token verification, two-tier
    LRU order (demote to host before evicting), paged entries evict
    rather than strand resident pages, page-allocator refcounts;
  * cancel-after-fork — a forked victim's eviction never perturbs
    sibling forks of the same entry, and the entry survives for later
    admissions;
  * a mesh-sharded engine snapshot round-trip (host demotion →
    mesh-aware promotion) — runs in the multidevice CI job, skips at
    1 device.
"""
import dataclasses
import random

import jax
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import lm
from repro.serving import (NoFreePages, PageAllocator, PrefixCache,
                           PrefixCacheConfig, Request, ServingEngine)

PC = PrefixCacheConfig(block_tokens=8, page_size=8)


def _cfg(kind: str, **kw):
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg = cfgs.darkify(cfg, kind, cfg.attn.num_features)
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _params(cfg):
    return lm.init_params(jax.random.PRNGKey(0), cfg)


def _prefix(vocab, n=16, seed=42):
    rng = random.Random(seed)
    return [rng.randrange(vocab) for _ in range(n)]


def _sharers(vocab, prefix, *, n=5, seed=0, temperature=0.0,
             sampled_mix=False):
    """Prefix-sharing requests with PINNED uids so the per-row sample
    keys (and hence sampled streams) are comparable across engines."""
    rng = random.Random(seed)
    reqs = []
    for i in range(n):
        kw = {}
        if sampled_mix and i % 3 == 1:
            kw = {"top_k": 7, "top_p": 0.9}
        suffix = [rng.randrange(vocab)
                  for _ in range(rng.randint(4, 10))]
        reqs.append(Request(prompt=list(prefix) + suffix,
                            max_new_tokens=rng.randint(3, 8),
                            temperature=temperature, uid=5000 + i, **kw))
    return reqs


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    return {r.uid: list(r.tokens) for r in eng.run()}


def _engine(params, cfg, *, cache, overlap=False, mesh=None, slots=3):
    return ServingEngine(params, cfg, max_slots=slots, max_len=64,
                         chunk_tokens=8, seed=0, overlap=overlap,
                         mesh=mesh, prefix_cache=cache)


def _primed_engine(params, cfg, prefix, **kw):
    """Cache-on engine whose store already holds the prefix (one primer
    request drained through it captures the block-aligned snapshots)."""
    eng = _engine(params, cfg, cache=PC, **kw)
    _drain(eng, [Request(prompt=list(prefix) + [1, 2, 3],
                         max_new_tokens=2, uid=4999)])
    assert eng.prefix_cache.has(prefix)
    return eng


# ---------------------------------------------------------------------------
# fork parity: forked streams bitwise-equal to cold-start
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["darkformer", "exact"])
@pytest.mark.parametrize("overlap", [False, True])
def test_fork_parity_greedy(kind, overlap):
    """Greedy prefix-sharing batch: every stream from the primed
    cache-on engine (every sharer forks the cached prefix) must equal
    the cache-less reference bitwise — PRF snapshot forks and exact
    paged copy-on-write forks, both schedulers."""
    cfg = _cfg(kind)
    params = _params(cfg)
    prefix = _prefix(cfg.vocab)
    ref = _drain(_engine(params, cfg, cache=None, overlap=overlap),
                 _sharers(cfg.vocab, prefix, seed=1))
    eng = _primed_engine(params, cfg, prefix, overlap=overlap)
    got = _drain(eng, _sharers(cfg.vocab, prefix, seed=1))
    st = eng.stats
    assert st["forked_requests"] >= 5 and st["forked_tokens"] > 0
    assert st["paged_kv"] == (kind == "exact")
    assert set(got) == set(ref)
    for uid in ref:
        assert got[uid] == ref[uid], uid


@pytest.mark.parametrize("overlap", [False, True])
def test_fork_parity_sampled(overlap):
    """Sampled storm (temperature 0.8, a third of the rows top-k/top-p):
    the per-row (uid, token-index) sample keys are fork-invariant, so
    even stochastic forked streams match cold-start bitwise."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prefix = _prefix(cfg.vocab)
    mk = lambda: _sharers(cfg.vocab, prefix, seed=2, temperature=0.8,
                          sampled_mix=True)
    ref = _drain(_engine(params, cfg, cache=None, overlap=overlap), mk())
    eng = _primed_engine(params, cfg, prefix, overlap=overlap)
    got = _drain(eng, mk())
    assert eng.stats["forked_requests"] >= 5
    for uid in ref:
        assert got[uid] == ref[uid], uid


def test_partial_prefix_match_forks_longest_block():
    """A prompt sharing only the first block of a longer cached prefix
    forks from the longest block-aligned snapshot, not the full entry."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prefix = _prefix(cfg.vocab, n=16)
    eng = _primed_engine(params, cfg, prefix)
    half = prefix[:8]
    ref = _drain(_engine(params, cfg, cache=None),
                 _sharers(cfg.vocab, half, n=2, seed=3))
    hits0 = eng.stats["prefix_hits"]
    got = _drain(eng, _sharers(cfg.vocab, half, n=2, seed=3))
    assert eng.stats["prefix_hits"] == hits0 + 2
    for uid in ref:
        assert got[uid] == ref[uid], uid


# ---------------------------------------------------------------------------
# cancel-after-fork
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("overlap", [False, True])
def test_cancel_after_fork(overlap):
    """Cancelling one forked request mid-decode must not perturb its
    sibling forks (they share the entry, not mutable state), and the
    cached entry must keep serving later admissions."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prefix = _prefix(cfg.vocab)
    reqs = _sharers(cfg.vocab, prefix, seed=4)
    ref = _drain(_engine(params, cfg, cache=None, overlap=overlap), reqs)

    eng = _primed_engine(params, cfg, prefix, overlap=overlap)
    reqs = _sharers(cfg.vocab, prefix, seed=4)
    victim = reqs[0]
    seen = []

    def hook(tok, t):
        seen.append(tok)
        if len(seen) == 2:
            eng.cancel(victim.uid)
    victim.on_token = hook
    got = _drain(eng, reqs)
    assert len(seen) == 2                      # in-flight work dropped
    for uid in ref:
        if uid != victim.uid:
            assert got[uid] == ref[uid], uid
    # the entry survives the cancel: a late admission still forks
    hits0 = eng.stats["prefix_hits"]
    late = _drain(eng, _sharers(cfg.vocab, prefix, n=1, seed=5))
    assert eng.stats["prefix_hits"] == hits0 + 1
    assert late


# ---------------------------------------------------------------------------
# the store: LRU tiers, verification, allocator
# ---------------------------------------------------------------------------

def _state(fill, n=256):
    return {"s": np.full((n,), fill, np.float32)}


def test_lru_demote_then_evict_order():
    """Strict LRU across both tiers: device overflow demotes the
    least-recently-used entries to host (in tick order), host overflow
    evicts them — and a match() bump rescues an entry from demotion."""
    nbytes = _state(0.0)["s"].nbytes
    pc = PrefixCache(PrefixCacheConfig(block_tokens=4,
                                       device_bytes=2 * nbytes,
                                       host_bytes=nbytes),
                     to_host=lambda t: t, to_device=lambda t: t)
    a, b, c, d = ([10 + i] * 4 for i in range(4))
    pc.put(a, _state(1.0))
    pc.put(b, _state(2.0))
    assert pc.match(a + [0]) is not None       # bump a: b is now LRU
    pc.put(c, _state(3.0))                     # device full -> demote b
    st = pc.stats
    assert st["prefix_demotions"] == 1 and st["prefix_evictions"] == 0
    assert st["prefix_device_bytes"] == 2 * nbytes
    assert st["prefix_host_bytes"] == nbytes
    pc.put(d, _state(4.0))                     # demote a -> host full
    st = pc.stats                              # -> evict b (host LRU)
    assert st["prefix_demotions"] == 2 and st["prefix_evictions"] == 1
    assert not pc.has(b) and pc.has(a) and pc.has(c) and pc.has(d)
    # promoting the host-tier survivor re-balances the device tier
    ent = pc.match(a + [0])
    out = pc.device_state(ent)
    np.testing.assert_array_equal(out["s"], _state(1.0)["s"])
    assert pc.stats["prefix_demotions"] == 3   # c or d made room


def test_match_verifies_tokens_and_respects_limit():
    """match() never returns a whole-prompt entry (>= 1 token must stay
    unprefilled) and verifies stored tokens, not just the hash."""
    pc = PrefixCache(PrefixCacheConfig(block_tokens=4),
                     to_host=lambda t: t, to_device=lambda t: t)
    toks = [1, 2, 3, 4]
    pc.put(toks, _state(1.0))
    assert pc.match(toks) is None              # nothing left to prefill
    assert pc.match(toks + [9]) is not None
    assert pc.match([1, 2, 3, 5, 6]) is None   # differing 4th token
    # stats counted: 2 misses, 1 hit
    assert pc.stats["prefix_hits"] == 1
    assert pc.stats["prefix_misses"] == 2


def test_paged_entries_evict_not_demote():
    """A paged entry's KV pages stay device-resident, so the rebalancer
    must EVICT it (releasing its pages) instead of demoting it."""
    released = []
    nbytes = _state(0.0)["s"].nbytes
    alloc = PageAllocator(8)

    def _release(ids):
        released.extend(ids)
        alloc.release(ids)

    pc = PrefixCache(PrefixCacheConfig(block_tokens=4,
                                       device_bytes=2 * nbytes),
                     to_host=lambda t: t, to_device=lambda t: t,
                     release_pages=_release)
    pages = alloc.alloc(2)
    pc.put([1] * 4, _state(1.0), pages=pages, page_bytes=nbytes)
    pc.put([2] * 4, _state(2.0))
    pc.put([3] * 4, _state(3.0))               # overflow: paged LRU out
    st = pc.stats
    assert st["prefix_evictions"] == 1 and st["prefix_demotions"] == 0
    assert released == pages and alloc.n_free == 7
    assert not pc.has([1] * 4)


def test_page_allocator_refcounts():
    """retain/release move refcounts; pages free only at zero; page 0
    is never handed out; exhaustion raises before mutating."""
    alloc = PageAllocator(4)
    ids = alloc.alloc(3)
    assert 0 not in ids and alloc.n_free == 0
    alloc.retain(ids[:1])
    alloc.release(ids)                         # ids[0] still retained
    assert alloc.n_free == 2
    with pytest.raises(NoFreePages):
        alloc.alloc(3)
    assert alloc.n_free == 2                   # alloc failed atomically
    alloc.release(ids[:1])
    assert alloc.n_free == 3


def test_match_unaligned_final_capture_length():
    """A capture_final entry at a non-block-aligned length is still a
    match candidate: candidates come from the lengths actually stored,
    not just the block grid — and the longest one wins."""
    pc = PrefixCache(PrefixCacheConfig(block_tokens=4),
                     to_host=lambda t: t, to_device=lambda t: t)
    pc.put([5, 6, 7, 8, 9, 10], _state(1.0))       # len 6: unaligned
    pc.put([5, 6, 7, 8], _state(2.0))
    ent = pc.match([5, 6, 7, 8, 9, 10, 11])
    assert ent is not None and len(ent.tokens) == 6


def test_match_hashes_in_one_rolling_pass(monkeypatch):
    """match() hashes the prompt ONCE (rolling digest, copied at each
    stored length), not once per block-aligned candidate — a miss on a
    long prompt costs O(len) blake2b work, not O(len^2/block_tokens)."""
    from repro.serving import prefix_cache as pc_mod
    pc = PrefixCache(PrefixCacheConfig(block_tokens=4),
                     to_host=lambda t: t, to_device=lambda t: t)
    for n in (4, 8, 16):
        pc.put(list(range(n)), _state(float(n)))
    calls = []
    real = pc_mod.hashlib.blake2b
    monkeypatch.setattr(
        pc_mod.hashlib, "blake2b",
        lambda *a, **k: calls.append(1) or real(*a, **k))
    ent = pc.match(list(range(16)) + [99] * 400)    # hit at length 16
    assert ent is not None and len(ent.tokens) == 16
    assert len(calls) == 1
    calls.clear()
    assert pc.match([77] * 400) is None             # long-prompt miss
    assert len(calls) == 1


def test_reclaim_pages_backpressure():
    """reclaim_pages evicts LRU paged entries until the pool can serve
    the request, and reports failure (engine defers) when it can't."""
    alloc = PageAllocator(6)
    pc = PrefixCache(PrefixCacheConfig(block_tokens=4),
                     to_host=lambda t: t, to_device=lambda t: t,
                     release_pages=alloc.release)
    pc.put([1] * 4, _state(1.0), pages=alloc.alloc(3), page_bytes=1)
    pc.put([2] * 4, _state(2.0), pages=alloc.alloc(2), page_bytes=1)
    assert pc.reclaim_pages(alloc, 3)          # evicts the LRU entry
    assert alloc.n_free == 3 and not pc.has([1] * 4)
    assert not pc.reclaim_pages(alloc, 6)      # even empty can't serve
    assert len(pc) == 0


def test_reclaim_pages_excludes_pinned_entry():
    """reclaim_pages(exclude=) never evicts the pinned entry — even as
    the last remaining paged entry it reports failure (the engine
    defers the admission) instead of dropping the pages the caller is
    about to share."""
    alloc = PageAllocator(6)
    pc = PrefixCache(PrefixCacheConfig(block_tokens=4),
                     to_host=lambda t: t, to_device=lambda t: t,
                     release_pages=alloc.release)
    a_pages = alloc.alloc(2)
    pc.put([1] * 4, _state(1.0), pages=a_pages, page_bytes=1)
    ent = pc.match([1] * 4 + [0])
    pc.put([2] * 4, _state(2.0), pages=alloc.alloc(2), page_bytes=1)
    assert not pc.reclaim_pages(alloc, 5, exclude=ent)
    assert pc.has([1] * 4) and not pc.has([2] * 4)
    assert alloc.n_free == 3                   # A's 2 pages resident
    assert all(alloc._ref[p] > 0 for p in a_pages)


def test_fork_admission_never_steals_matched_pages():
    """Exhausted page pool at fork admission: the reclaim must not
    evict the matched entry itself (pre-fix it could, releasing the
    shared prefix pages into the LIFO free list where alloc() re-issued
    them as the SAME request's writable growth pages — a double-booked
    table silently corrupting the prefix KV). The admission defers
    cleanly with refcounts unwound, the entry keeps serving, and the
    retried admission builds a duplicate-free table."""
    cfg = _cfg("exact")
    params = _params(cfg)
    prefix = _prefix(cfg.vocab)
    eng = _primed_engine(params, cfg, prefix)
    alloc = eng._alloc
    ent = eng.prefix_cache.match(list(prefix) + [0])
    assert ent is not None and len(ent.tokens) == len(prefix)
    hog = alloc.alloc(alloc.n_free)            # drain the free list
    req = Request(prompt=list(prefix) + [7] * 8, max_new_tokens=4,
                  uid=6001)
    with pytest.raises(NoFreePages):
        eng._paged_admit_pages(req, ent)
    # the matched entry survived its own reclaim with pages still owned
    assert eng.prefix_cache.has(prefix)
    assert all(alloc._ref[p] > 0 for p in ent.pages)
    alloc.release(hog)
    table, own, copies = eng._paged_admit_pages(req, ent)
    assert len(set(own)) == len(own)           # no double-booked pages
    assert set(ent.pages).issubset(own)        # prefix pages shared
    assert all(alloc._ref[p] >= 2 for p in ent.pages)


def test_misaligned_block_tokens_rejected():
    """block_tokens must divide chunk_tokens (capture points fire only
    on exact block boundaries) — validated at engine init instead of
    silently capturing nothing."""
    cfg = _cfg("darkformer")
    params = _params(cfg)
    with pytest.raises(ValueError, match="block_tokens"):
        ServingEngine(params, cfg, max_slots=2, max_len=64,
                      chunk_tokens=12,
                      prefix_cache=PrefixCacheConfig(block_tokens=8))


# ---------------------------------------------------------------------------
# engine stats surface
# ---------------------------------------------------------------------------

def test_engine_stats_surface():
    cfg = _cfg("exact")
    params = _params(cfg)
    prefix = _prefix(cfg.vocab)
    eng = _primed_engine(params, cfg, prefix)
    _drain(eng, _sharers(cfg.vocab, prefix, n=2, seed=6))
    st = eng.stats
    assert st["paged_kv"] is True
    for key in ("prefix_hit_rate", "prefix_captures", "forked_tokens",
                "prefix_device_bytes", "kv_page_size", "kv_pages_total",
                "kv_pages_free"):
        assert key in st, key
    assert 0 < st["kv_pages_free"] < st["kv_pages_total"]


# ---------------------------------------------------------------------------
# mesh-sharded snapshots (multidevice CI job)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (multidevice CI job)")
def test_mesh_sharded_snapshot_roundtrip():
    """Cache-on engine over a mesh-sharded slot pool: snapshots are
    captured sharded, demoted to host numpy, and promoted back through
    the mesh-aware ``to_device`` — forked streams must still equal the
    unsharded cache-less reference bitwise."""
    from repro.launch.mesh import make_local_mesh
    cfg = _cfg("darkformer")
    params = _params(cfg)
    prefix = _prefix(cfg.vocab)
    ref = _drain(_engine(params, cfg, cache=None, slots=4),
                 _sharers(cfg.vocab, prefix, seed=7))
    mesh = make_local_mesh(2, 1)
    eng = _primed_engine(params, cfg, prefix, mesh=mesh, slots=4)
    # force the captured entries through the host tier so the promote
    # path (mesh-aware device_put) is what serves the forks
    for ent in eng.prefix_cache._entries.values():
        ent.state = jax.device_get(ent.state)
        ent.on_host = True
    got = _drain(eng, _sharers(cfg.vocab, prefix, seed=7))
    assert eng.stats["forked_requests"] >= 5
    for uid in ref:
        assert got[uid] == ref[uid], uid
