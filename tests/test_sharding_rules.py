"""Sharding-rule logic (multi-device: subprocess with 8 fake devices)."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_rules_divisibility_and_overrides():
    print(run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.sharding import param_specs, _divisible
        from repro import configs as cfgs
        from repro.models import lm

        mesh = make_local_mesh(2, 4)
        # 1) _divisible drops non-dividing dims
        assert _divisible((6, 8), P("model", "data"), mesh) == P(None, "data")
        assert _divisible((8, 8), P("model", "data"), mesh) == P("model", "data")
        assert _divisible((8,), P(("model", "data")), mesh) == P(("model", "data"))
        assert _divisible((4,), P(("model", "data")), mesh) == P(None)

        # 2) embed spec: vocab over model, d replicated (the logits rule)
        cfg = cfgs.get_config("smollm-135m", reduced=True)
        ps = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                            jax.random.PRNGKey(0))
        specs = param_specs(ps, mesh)
        assert tuple(specs["embed"]) == ("model", None), specs["embed"]

        # 3) per-arch overrides take precedence (granite-moe pins its ffn)
        cfgm = cfgs.get_config("granite-moe-3b-a800m", reduced=True)
        cfgm_full = cfgs.get_config("granite-moe-3b-a800m")
        assert cfgm_full.sharding_overrides
        psm = jax.eval_shape(lambda k: lm.init_params(k, cfgm_full),
                             jax.random.PRNGKey(0))
        specsm = param_specs(psm, mesh, moe=True,
                             overrides=cfgm_full.sharding_overrides)
        wg = specsm["units"]["b0"]["ffn"]["w_gate"]
        # scanned leading None + (None, "data", "model") from the override
        assert tuple(wg) == (None, None, "data", "model"), wg

        # 4) EP fallback triggers when experts don't divide 'model'
        from repro.parallel.sharding import _MOE_RULES_TP
        specs_nofb = param_specs(psm, mesh, moe=True)  # no overrides
        # 40 % 4 == 0 on this mesh -> EP rules apply (experts on model)
        wg2 = specs_nofb["units"]["b0"]["ffn"]["w_gate"]
        assert tuple(wg2)[1] == "model", wg2
        print("RULES_OK")
    """))


def test_fsdp_preset_batch_and_params():
    print(run_py("""
        import jax
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.sharding import param_specs, batch_specs
        from repro import configs as cfgs
        from repro.models import lm
        import numpy as np

        mesh = make_local_mesh(2, 4)
        cfg = cfgs.get_config("smollm-135m", reduced=True)
        ps = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                            jax.random.PRNGKey(0))
        specs = param_specs(ps, mesh, preset="fsdp")
        # largest dim of embed (vocab=256) sharded over both axes
        assert tuple(specs["embed"]) == (("data", "model"), None), specs["embed"]
        b = {"tokens": jax.ShapeDtypeStruct((16, 8), jax.numpy.int32)}
        bs = batch_specs(b, mesh, preset="fsdp")
        assert tuple(bs["tokens"])[0] == ("data", "model")
        print("FSDP_OK")
    """))
