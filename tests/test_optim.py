"""AdamW, schedules, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, adamw_init, adamw_update, global_norm,
                         clip_by_global_norm, cosine_warmup, linear_warmup)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=1e9)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params, cfg)
    for step in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, g, state, cfg, cfg.lr)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_weight_decay_shrinks():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5)
    params = {"w": jnp.ones(4) * 10}
    state = adamw_init(params, cfg)
    zero_g = {"w": jnp.zeros(4)}
    p1, _, _ = adamw_update(params, zero_g, state, cfg, cfg.lr)
    assert float(jnp.abs(p1["w"]).max()) < 10.0


def test_grad_clip():
    tree = {"a": jnp.ones(100) * 10}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4
    assert float(norm) == pytest.approx(100.0, rel=1e-4)


def test_bf16_params_f32_moments():
    cfg = AdamWConfig(lr=0.01)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["mu"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones(4, jnp.bfloat16) * 0.5}
    p1, s1, m = adamw_update(params, g, state, cfg, 0.01)
    assert p1["w"].dtype == jnp.bfloat16
    assert s1["nu"]["w"].dtype == jnp.float32


def test_factored_second_moment_close_to_full():
    cfg_full = AdamWConfig(lr=0.05, factored_second_moment=False,
                           weight_decay=0.0)
    cfg_fact = AdamWConfig(lr=0.05, factored_second_moment=True,
                           weight_decay=0.0)
    key = jax.random.PRNGKey(0)
    w0 = jax.random.normal(key, (8, 8))
    target = jax.random.normal(jax.random.fold_in(key, 1), (8, 8))
    outs = []
    for cfg in (cfg_full, cfg_fact):
        params = {"w": w0}
        state = adamw_init(params, cfg)
        for step in range(200):
            g = {"w": params["w"] - target}
            params, state, _ = adamw_update(params, g, state, cfg, cfg.lr)
        outs.append(params["w"])
    err = float(jnp.abs(outs[0] - target).max())
    err_f = float(jnp.abs(outs[1] - target).max())
    assert err < 0.05 and err_f < 0.15


def test_schedules():
    s = cosine_warmup(1.0, 10, 100)
    assert float(s(0)) < 0.2
    assert float(s(10)) == pytest.approx(1.0, abs=0.05)
    assert float(s(99)) < 0.2
    lw = linear_warmup(2.0, 4)
    assert float(lw(0)) == pytest.approx(0.5)
    assert float(lw(100)) == pytest.approx(2.0)
