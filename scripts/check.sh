#!/usr/bin/env bash
# Tier-1 verify, reproducible from a fresh checkout:
#   pip install -r requirements.txt -r requirements-dev.txt
#   scripts/check.sh
# Mirrors ROADMAP.md's verify line exactly; any extra args are passed
# through to pytest (e.g. scripts/check.sh -k serving).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
