#!/usr/bin/env bash
# Tier-1 verify, reproducible from a fresh checkout:
#   pip install -r requirements.txt -r requirements-dev.txt
#   scripts/check.sh              # full tier-1 suite (incl. interpret-mode
#                                 # Pallas kernel tests)
#   scripts/check.sh --fast       # skips @pytest.mark.slow (multi-device
#                                 # subprocess + launcher integration tests)
# Mirrors ROADMAP.md's verify line exactly; any extra args are passed
# through to pytest (e.g. scripts/check.sh -k serving).
set -euo pipefail
cd "$(dirname "$0")/.."
ARGS=()
for a in "$@"; do
  if [ "$a" = "--fast" ]; then
    ARGS+=(-m "not slow")
  else
    ARGS+=("$a")
  fi
done
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  exec python -m pytest -x -q ${ARGS+"${ARGS[@]}"}
