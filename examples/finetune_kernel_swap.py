"""The paper's main scenario (Fig. 2 bottom / Fig. 4): take a pretrained
exact-attention model, swap in the DARKFormer kernel (pure config change),
whitening-calibrate the covariance from one batch (App. C), and finetune —
optionally q/k/v + M only (limited-attention finetuning).

    PYTHONPATH=src python examples/finetune_kernel_swap.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import FeatureConfig
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step, qkv_only_freeze
from repro.models import ModelConfig, init_params, lm
from repro.optim import AdamWConfig, adamw_init
from repro.optim.schedules import constant

base = ModelConfig(name="ft", n_layers=4, d_model=64, n_heads=4, n_kv=1,
                   d_ff=128, vocab=256, remat="none",
                   attn=FeatureConfig(kind="exact"))
data = SyntheticLM(base.vocab, 64, 8)

# --- pretrain with exact softmax attention ---
params = init_params(jax.random.PRNGKey(0), base)
opt_cfg = AdamWConfig(lr=3e-3)
opt = adamw_init(params, opt_cfg)
step = jax.jit(make_train_step(base, opt_cfg, constant(3e-3)))
for i in range(80):
    params, opt, m = step(params, opt, dict(data.batch(i)), jnp.int32(i))
print(f"pretrained (exact): loss {float(m['loss']):.4f}")

# --- swap kernel: exact -> darkformer (adds feat params; rest transplants)
cfg_d = dataclasses.replace(
    base, attn=FeatureConfig(kind="darkformer", num_features=16))
p_dark = init_params(jax.random.PRNGKey(1), cfg_d)
src = {jax.tree_util.keystr(k): v for k, v in
       jax.tree_util.tree_flatten_with_path(params)[0]}
flat, tdef = jax.tree_util.tree_flatten_with_path(p_dark)
p_dark = jax.tree_util.tree_unflatten(
    tdef, [src.get(jax.tree_util.keystr(k), v) for k, v in flat])

# --- whitening calibration: M = Lambda^{-1/2} from one batch (App. C) ---
p_dark = lm.whitening_calibrate(p_dark, cfg_d, dict(data.batch(10_000)))
print("covariance calibrated from one batch")

# --- limited finetuning: only q/k/v and the PRF covariance M train ---
opt = adamw_init(p_dark, opt_cfg)
step_ft = jax.jit(make_train_step(cfg_d, opt_cfg, constant(1e-3),
                                  freeze=qkv_only_freeze))
for i in range(60):
    p_dark, opt, m = step_ft(p_dark, opt, dict(data.batch(1000 + i)),
                             jnp.int32(i))
print(f"finetuned (darkformer, q/k/v+M only): loss {float(m['loss']):.4f}")
