"""Fault-tolerant training: checkpoint/restart with an injected failure,
straggler monitoring, and an elastic-shrink plan — the 1000-node posture
exercised end to end on CPU.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.core import FeatureConfig
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import ModelConfig, init_params
from repro.optim import AdamWConfig, adamw_init
from repro.optim.schedules import constant
from repro.runtime import (TrainSupervisor, StragglerMonitor,
                           elastic_shrink_plan)

cfg = ModelConfig(name="ft-demo", n_layers=2, d_model=48, n_heads=4,
                  n_kv=2, d_ff=96, vocab=128, remat="none",
                  attn=FeatureConfig(kind="darkformer", num_features=16))
opt_cfg = AdamWConfig(lr=1e-3)
params = init_params(jax.random.PRNGKey(0), cfg)
state = {"params": params, "opt": adamw_init(params, opt_cfg)}
data = SyntheticLM(cfg.vocab, 32, 4)
jstep = jax.jit(make_train_step(cfg, opt_cfg, constant(1e-3)))


def step_fn(state, i):
    p, o, m = jstep(state["params"], state["opt"], dict(data.batch(i)),
                    jnp.int32(i))
    if i % 10 == 0:
        print(f"  step {i:3d} loss {float(m['loss']):.4f}")
    return {"params": p, "opt": o}


with tempfile.TemporaryDirectory() as ckpt_dir:
    sup = TrainSupervisor(ckpt_dir, ckpt_every=10,
                          monitor=StragglerMonitor(threshold=3.0))
    print("training 40 steps with a simulated node failure at step 25:")
    final = sup.run(state, step_fn, 40, fail_at=25)
    print("recovered and completed; stragglers flagged:",
          sup.monitor.straggler_steps)

print("elastic plan after losing 3 hosts from a (16,16) mesh:",
      elastic_shrink_plan((16, 16), ("data", "model"), 3))
