"""O(1)-state long-context serving: the PRF decode state is (m x d_v) per
head REGARDLESS of context length — 32k and 500k contexts cost the same
(the paper's headline efficiency property; compare the KV-cache numbers).

    PYTHONPATH=src python examples/long_context_serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.models import lm


def state_bytes(state):
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(state)
               if hasattr(x, "size"))


cfg = cfgs.get_config("smollm-135m", reduced=True)            # darkformer
cfg_exact = cfgs.darkify(cfg, "exact")
params = lm.init_params(jax.random.PRNGKey(0), cfg)
params_e = lm.init_params(jax.random.PRNGKey(0), cfg_exact)
tok = jnp.zeros((1,), jnp.int32)

print(f"{'context':>10s} {'PRF state':>12s} {'KV cache':>12s} "
      f"{'PRF us/tok':>11s}")
for ctx in (1024, 8192, 65536):
    st = lm.init_serve_state(cfg, b=1, max_len=ctx)
    st_e = lm.init_serve_state(cfg_exact, b=1, max_len=ctx)
    dec = jax.jit(lambda p, t, s: lm.decode_step(p, cfg, t, s))
    _, st2 = dec(params, tok, st)               # compile
    t0 = time.perf_counter()
    for _ in range(10):
        _, st2 = dec(params, tok, st2)
    jax.block_until_ready(st2["pos"])
    us = (time.perf_counter() - t0) / 10 * 1e6
    print(f"{ctx:10d} {state_bytes(st)/1e3:10.1f}KB "
          f"{state_bytes(st_e)/1e3:10.1f}KB {us:11.0f}")
print("PRF state & decode cost are context-independent; the KV cache "
      "grows linearly.")
