"""Quickstart: build a DARKFormer model, train it, serve from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import FeatureConfig
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step, make_prefill_step, \
    make_decode_step
from repro.models import ModelConfig, init_params, lm
from repro.optim import AdamWConfig, adamw_init
from repro.optim.schedules import cosine_warmup

# 1. A small model with the paper's data-aware PRF attention.
cfg = ModelConfig(
    name="quickstart", n_layers=4, d_model=64, n_heads=4, n_kv=2,
    d_ff=128, vocab=256, remat="none",
    attn=FeatureConfig(kind="darkformer", num_features=32))
params = init_params(jax.random.PRNGKey(0), cfg)
n = sum(x.size for x in jax.tree_util.tree_leaves(params))
print(f"model: {n/1e6:.2f}M params, attention kernel = {cfg.attn.kind}")

# 2. Train for a few steps on the deterministic synthetic corpus.
opt_cfg = AdamWConfig(lr=3e-3)
opt = adamw_init(params, opt_cfg)
step = jax.jit(make_train_step(cfg, opt_cfg, cosine_warmup(3e-3, 10, 60)))
data = SyntheticLM(cfg.vocab, seq_len=64, batch_size=8)
for i in range(60):
    params, opt, metrics = step(params, opt, dict(data.batch(i)),
                                jnp.int32(i))
    if i % 20 == 0 or i == 59:
        print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
              f"acc {float(metrics['accuracy']):.3f}")

# 3. Serve: prefill a prompt, then O(1)-state greedy decode.
prompt = dict(data.batch(999))["tokens"][:2, :16]
prefill = jax.jit(make_prefill_step(cfg, max_len=64))
decode = jax.jit(make_decode_step(cfg))
logits, state = prefill(params, {"tokens": prompt})
tok = jnp.argmax(logits[:, -1], -1)
out = [tok]
for _ in range(12):
    logits, state = decode(params, tok, state)
    tok = jnp.argmax(logits, -1)
    out.append(tok)
print("generated:", jnp.stack(out, 1)[0].tolist())
