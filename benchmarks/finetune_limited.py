"""Paper Fig. 4: limited-attention finetuning — freeze everything except
q/k/v projections (+ DARKFormer's covariance M). The frozen network can't
re-shape its representations toward isotropy, so the data-aware kernel's
advantage persists instead of fading."""
from __future__ import annotations

import jax

from repro.models import lm
from repro.data import SyntheticLM
from repro.launch.steps import qkv_only_freeze
from benchmarks.common import (bench_cfg, train, transplant, save_result,
                               SEQ, BATCH)
from benchmarks.finetune_curves import pretrain_base


def run(fast: bool = True, base=None) -> dict:
    steps = 400 if fast else 2000
    cfg_e, p_exact, _ = base or pretrain_base(fast)
    data = SyntheticLM(cfg_e.vocab, SEQ, BATCH, seed=7)
    curves = {}
    for kernel in ("exact", "darkformer", "performer"):
        cfg = bench_cfg(kernel)
        params = transplant(p_exact, lm.init_params(
            jax.random.PRNGKey(1), cfg))
        if kernel == "darkformer":
            params = lm.whitening_calibrate(params, cfg,
                                            dict(data.batch(99_998)))
        _, hist = train(cfg, steps, lr=1e-3, seed=1, params=params,
                        warmup=10, freeze=qkv_only_freeze, record_every=20)
        curves[kernel] = hist
        print(f"  limited-ft[{kernel}]: "
              f"final={hist[-1]['eval_accuracy']:.4f}", flush=True)
    final = {k: v[-1]["eval_accuracy"] for k, v in curves.items()}
    gap = final["darkformer"] - final["performer"]
    out = {"curves": curves, "final": final, "dark_vs_perf_gap": gap,
           "us_per_call": 0.0, "derived": gap}
    save_result("finetune_limited", out)
    return out


if __name__ == "__main__":
    r = run()
    print("final:", {k: round(v, 4) for k, v in r["final"].items()})
