"""Paper Fig. 2 (top): pretraining accuracy, all six attention kernels.

DARKFormer vs Performer vs LFK vs exact softmax vs random/constant
baselines, identical data/hyperparameters (paper §6). Reduced scale: the
bench model from benchmarks.common, small feature budget m=16.
"""
from __future__ import annotations

from benchmarks.common import bench_cfg, train, save_result

KERNELS = ("exact", "darkformer", "performer", "lfk", "random", "constant")


def run(fast: bool = True, steps: int = None) -> dict:
    steps = steps or (250 if fast else 1200)
    curves = {}
    for kernel in KERNELS:
        cfg = bench_cfg(kernel)
        _, hist = train(cfg, steps, lr=3e-3, seed=0)
        curves[kernel] = hist
        print(f"  pretrain[{kernel}]: final eval_acc="
              f"{hist[-1]['eval_accuracy']:.4f} loss={hist[-1]['loss']:.4f}",
              flush=True)
    final = {k: v[-1]["eval_accuracy"] for k, v in curves.items()}
    # headline: how much of the performer->exact gap darkformer closes
    gap_perf = final["exact"] - final["performer"]
    gap_dark = final["exact"] - final["darkformer"]
    closed = 1.0 - gap_dark / gap_perf if abs(gap_perf) > 1e-9 else 0.0
    us = sum(h["dt"] for h in curves["darkformer"][1:]) / max(
        1, len(curves["darkformer"]) - 1) * 1e6
    out = {"curves": curves, "final": final, "gap_closed": closed,
           "us_per_call": us, "derived": closed}
    save_result("pretrain_curves", out)
    return out


if __name__ == "__main__":
    r = run()
    print("final:", {k: round(v, 4) for k, v in r["final"].items()})
    print("gap closed by darkformer:", round(r["gap_closed"], 3))
