"""Paper Fig. 5: training stability across learning rates. Finetune with a
sweep of LRs; count loss spikes (step-to-step loss jumps above a
threshold) and divergences. DARKFormer's Mahalanobis whitening tempers
extreme dot products -> fewer spikes at large LR."""
from __future__ import annotations

import math

import jax

from repro.models import lm
from repro.data import SyntheticLM
from benchmarks.common import (bench_cfg, train, transplant, save_result,
                               SEQ, BATCH)
from benchmarks.finetune_curves import pretrain_base

LRS = (1e-3, 3e-3, 1e-2, 3e-2, 1e-1)


def spikes(hist, jump=0.25):
    losses = [h["loss"] for h in hist]
    n = sum(1 for a, b in zip(losses, losses[1:])
            if (b > a + jump) or not math.isfinite(b))
    diverged = (not math.isfinite(losses[-1])) or losses[-1] > losses[0] + 1
    return n, diverged


def run(fast: bool = True, base=None) -> dict:
    steps = 150 if fast else 600
    cfg_e, p_exact, _ = base or pretrain_base(fast)
    data = SyntheticLM(cfg_e.vocab, SEQ, BATCH, seed=7)
    rows = []
    for kernel in ("darkformer", "performer"):
        for lr in LRS:
            cfg = bench_cfg(kernel)
            params = transplant(p_exact, lm.init_params(
                jax.random.PRNGKey(1), cfg))
            if kernel == "darkformer":
                params = lm.whitening_calibrate(
                    params, cfg, dict(data.batch(99_998)))
            _, hist = train(cfg, steps, lr=lr, seed=1, params=params,
                            warmup=5, record_every=2, eval_batches=1)
            n_spikes, diverged = spikes(hist)
            rows.append({"kernel": kernel, "lr": lr, "spikes": n_spikes,
                         "diverged": diverged,
                         "final_loss": hist[-1]["loss"]})
            print(f"  lr_stability[{kernel} lr={lr}]: spikes={n_spikes} "
                  f"diverged={diverged}", flush=True)
    tot = {k: sum(r["spikes"] for r in rows if r["kernel"] == k)
           for k in ("darkformer", "performer")}
    out = {"rows": rows, "total_spikes": tot, "us_per_call": 0.0,
           "derived": tot["performer"] - tot["darkformer"]}
    save_result("lr_stability", out)
    return out


if __name__ == "__main__":
    r = run()
    print("total spikes:", r["total_spikes"])
