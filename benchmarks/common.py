"""Shared bench harness: the scaled-down Gemma-style model + train loops.

The paper's experiments (Fig. 2-5) run Gemma-2B on C4; this container is a
single CPU core, so benches run a width/depth-reduced model of the same
family (MQA + GeGLU, embed scaling) on the synthetic bigram corpus, with a
deliberately small feature budget (m = 16) — the regime where sampling
geometry matters. All comparisons are RELATIVE (dark vs performer vs exact
vs baselines), matching the paper's claims rather than its absolute
numbers.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.feature_maps import FeatureConfig
from repro.data import SyntheticLM
from repro.launch import steps as steps_lib
from repro.models import ModelConfig, lm
from repro.optim import AdamWConfig, adamw_init
from repro.optim.schedules import cosine_warmup, constant

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "bench")

SEQ = 64
BATCH = 8
VOCAB = 256


def bench_cfg(kernel: str = "darkformer", m: int = 16,
              scan: bool = True) -> ModelConfig:
    return ModelConfig(
        name=f"bench-{kernel}", n_layers=4, d_model=64, n_heads=4, n_kv=1,
        d_head=16, d_ff=192, vocab=VOCAB, mlp_kind="geglu",
        embed_scale=True, tie_embeddings=True, remat="none",
        scan_layers=scan,
        attn=FeatureConfig(kind=kernel, num_features=m, orthogonal=True))


def transplant(src_params, dst_params):
    """Copy every shared leaf from src to dst (checkpoint surgery for
    kernel switches: exact -> PRF adds feat params, everything else moves)."""
    flat_src = {jax.tree_util.keystr(k): v for k, v in
                jax.tree_util.tree_flatten_with_path(src_params)[0]}
    flat_dst, tdef = jax.tree_util.tree_flatten_with_path(dst_params)
    merged = [flat_src.get(jax.tree_util.keystr(k), v)
              for k, v in flat_dst]
    return jax.tree_util.tree_unflatten(tdef, merged)


def train(cfg: ModelConfig, steps: int, lr: float, *, seed: int = 0,
          params=None, freeze: Optional[Callable] = None,
          record_every: int = 10, warmup: int = 20,
          data: Optional[SyntheticLM] = None,
          eval_batches: int = 2) -> tuple[dict, list[dict]]:
    """Train and record {step, loss, accuracy, eval_accuracy, dt}."""
    data = data or SyntheticLM(cfg.vocab, SEQ, BATCH, seed=7)
    eval_data = SyntheticLM(cfg.vocab, SEQ, BATCH, seed=7, host=13)
    if params is None:
        params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    opt_cfg = AdamWConfig(lr=lr)
    opt = adamw_init(params, opt_cfg)
    sched = cosine_warmup(lr, warmup, steps) if warmup else constant(lr)
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt_cfg, sched,
                                                freeze))
    eval_fn = jax.jit(steps_lib.make_eval_step(cfg))
    history = []
    t_last = time.time()
    for s in range(steps):
        params, opt, m = step_fn(params, opt, dict(data.batch(s)),
                                 jnp.int32(s))
        if s % record_every == 0 or s == steps - 1:
            accs = [float(eval_fn(params, dict(eval_data.batch(10_000 + i))
                                  )["accuracy"]) for i in range(eval_batches)]
            now = time.time()
            history.append({
                "step": s, "loss": float(m["loss"]),
                "accuracy": float(m["accuracy"]),
                "eval_accuracy": sum(accs) / len(accs),
                "grad_norm": float(m["grad_norm"]),
                "dt": now - t_last})
            t_last = now
    return params, history


def time_call(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (jit-compiled fns)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def save_result(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load_result(name: str) -> Optional[dict]:
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None
