"""Paper Fig. 1 / §2: linear vs quadratic attention cost. Wall-clock of
exact softmax attention vs PRF linear attention (chunked kernel path and
pure-jnp path) as sequence length grows, fixed m. Also the serving angle:
decode state size O(m*dv) vs KV cache O(L*d)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (FeatureConfig, init_feature_params, rf_attention)
from benchmarks.common import save_result, time_call


def run(fast: bool = True) -> dict:
    B, G, Hg, d, m = 1, 1, 4, 32, 64
    lengths = (128, 256, 512, 1024) if fast else (128, 256, 512, 1024,
                                                  2048, 4096)
    cfg_lin = FeatureConfig(kind="darkformer", num_features=m)
    fp = init_feature_params(jax.random.PRNGKey(0), cfg_lin, d, n_groups=G)
    cfg_ex = FeatureConfig(kind="exact")
    rows = []
    for L in lengths:
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(L), 3)
        q = jax.random.normal(kq, (B, G, Hg, L, d)) * 0.5
        k = jax.random.normal(kk, (B, G, 1, L, d)) * 0.5
        v = jax.random.normal(kv, (B, G, 1, L, d))
        f_ex = jax.jit(lambda q, k, v: rf_attention(q, k, v, None, cfg_ex))
        f_lin = jax.jit(lambda q, k, v: rf_attention(q, k, v, fp, cfg_lin))
        t_ex = time_call(f_ex, q, k, v, iters=5)
        t_lin = time_call(f_lin, q, k, v, iters=5)
        rows.append({"L": L, "us_exact": t_ex, "us_linear": t_lin,
                     "speedup": t_ex / t_lin})
        print(f"  attn_scaling L={L}: exact={t_ex:.0f}us "
              f"linear={t_lin:.0f}us speedup={t_ex/t_lin:.2f}x", flush=True)
    # decode state: linear is O(m*dv) regardless of context
    kv_bytes_32k = 2 * 32_768 * d * 4            # k+v cache, f32
    lin_bytes = (m * d + m) * 4
    out = {"rows": rows,
           "kv_cache_bytes_32k_per_head": kv_bytes_32k,
           "linear_state_bytes_per_head": lin_bytes,
           "state_ratio": kv_bytes_32k / lin_bytes,
           "us_per_call": rows[-1]["us_linear"],
           "derived": rows[-1]["speedup"]}
    save_result("attn_scaling", out)
    return out


if __name__ == "__main__":
    r = run()
    print("state compression at 32k:", round(r["state_ratio"], 1), "x")
