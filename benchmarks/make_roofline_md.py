"""Render EXPERIMENTS.md §Roofline table from the dry-run artifacts."""
from __future__ import annotations

from benchmarks.roofline import load_all


def main():
    rows = load_all(mesh="pod")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    print("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "dominant | useful | roofline |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
              f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
              f"{r['dominant']} | {r['model_flops_ratio']:.2f} | "
              f"{r['roofline_frac']:.3f} |")
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print()
    print("dominant-term census:", doms)
    tr = [r for r in rows if r["shape"] == "train_4k"]
    if tr:
        best = max(tr, key=lambda r: r["roofline_frac"])
        worst = min(tr, key=lambda r: r["roofline_frac"])
        print(f"train cells roofline: best {best['arch']} "
              f"{best['roofline_frac']:.3f}, worst {worst['arch']} "
              f"{worst['roofline_frac']:.3f}")


if __name__ == "__main__":
    main()
