"""Prefill hot-path bench: fused megakernel vs two-stage-kernel vs jnp.

Measures the serving engine's OTHER steady-state unit of work — one
packed (P, L) prefill chunk — across chunk sizes and ragged-row mixes,
through three implementations of the resumed PRF prefill:

  * ``jnp``        — pure-jnp feature map + carried-state chunked scan
    (``rf_attention_prefill(use_kernel=False)``);
  * ``two_stage``  — the pre-ISSUE-5 Pallas path: jnp
    ``_resume_qk_features`` (featmap + running-max rescale + valid_len
    masking in XLA) + the ``linear_attn_scan`` carry kernel, with the
    (N, L, m) feature tensors round-tripping HBM between the stages;
  * ``fused``      — the ``prf_fused_prefill`` megakernel: projection,
    exp feature map, in-kernel running-max stabilizer carry, in-kernel
    valid_len masking, causal scan and (S, z, c) advance in ONE
    pallas_call per layer per chunk, state aliased in place.

Two levels: raw attention-op chunk latency (isolates the kernel change)
and full ``lm.prefill_chunk`` latency / prompt-tokens/s on the reduced
bench model (includes the layer-stacked scan the engine runs). Snapshot
written to ``experiments/bench/BENCH_prefill.json`` with the
methodology recorded — on this CPU container the kernels run in
interpret mode, so absolute numbers are simulation-level; the RELATIVE
ordering (what the trajectory tracks) is the claim. Schema is validated
on every write and by the CI bench-smoke job (``--validate``).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

import jax
import jax.numpy as jnp

from repro.core import attention as rfa
from repro.core import feature_maps as fm
from repro.models import lm
from benchmarks.common import bench_cfg, load_result, save_result, \
    time_call

SCHEMA_VERSION = 1
REQUIRED_ROW_KEYS = ("chunk", "rows", "ragged_frac", "us_jnp",
                     "us_two_stage", "us_fused",
                     "fused_speedup_vs_two_stage", "prompt_tok_s_fused")
REQUIRED_LM_KEYS = ("chunk", "rows", "us_jnp", "us_two_stage", "us_fused",
                    "prompt_tok_s_fused")


def _ragged_lens(p: int, l: int, frac: float) -> jnp.ndarray | None:
    """valid_len mix: ``frac`` of the rows cut to staggered partial
    lengths (incl. one pure-padding row when there is room), the rest
    full — the shape of a packer burst mid-drain."""
    if frac <= 0:
        return None
    lens = [l] * p
    n_ragged = max(1, int(p * frac))
    cuts = [0, l // 4, l // 2, 3 * l // 4]
    for j in range(n_ragged):
        lens[p - 1 - j] = cuts[j % len(cuts)]
    return jnp.asarray(lens, jnp.int32)


def run_attention_level(chunk_sizes, *, p=8, g=1, hg=4, d=16, m=32,
                        ragged_fracs=(0.0, 0.5), iters=16) -> list[dict]:
    """Per-chunk latency of the resumed prefill attention op, three ways."""
    cfg = fm.FeatureConfig(kind="darkformer", num_features=m)
    fparams = fm.init_feature_params(jax.random.PRNGKey(0), cfg, d,
                                     n_groups=g)
    proj = fm.precompose_projection(fparams, cfg.kind)
    rows = []
    for l in chunk_sizes:
        for frac in ragged_fracs:
            state = rfa.init_linear_serve_state(p, g, hg, m, d)
            key = jax.random.PRNGKey(l + int(frac * 10))
            q = jax.random.normal(key, (p, g, hg, l, d))
            k = jax.random.normal(jax.random.fold_in(key, 1),
                                  (p, g, 1, l, d))
            v = jax.random.normal(jax.random.fold_in(key, 2),
                                  (p, g, 1, l, d))
            vl = _ragged_lens(p, l, frac)

            def mk(**kw):
                return jax.jit(
                    lambda q, k, v, s, vl: rfa.rf_attention_prefill(
                        q, k, v, fparams, cfg, state=s, valid_len=vl,
                        **kw))

            fns = {"jnp": mk(),
                   "two_stage": mk(use_kernel=True),
                   "fused": mk(use_kernel=True, proj=proj)}
            row = {"chunk": l, "rows": p, "ragged_frac": frac}
            for name, fn in fns.items():
                row[f"us_{name}"] = time_call(
                    lambda fn=fn: fn(q, k, v, state, vl), iters=iters)
            row["fused_speedup_vs_two_stage"] = (
                row["us_two_stage"] / max(row["us_fused"], 1e-9))
            toks = p * l if vl is None else int(vl.sum())
            row["prompt_tok_s_fused"] = toks / (row["us_fused"] * 1e-6)
            rows.append(row)
            print(f"  attn chunk={l} ragged={frac}: "
                  f"jnp={row['us_jnp']:.0f}us "
                  f"two-stage={row['us_two_stage']:.0f}us "
                  f"fused={row['us_fused']:.0f}us "
                  f"({row['fused_speedup_vs_two_stage']:.2f}x, "
                  f"{row['prompt_tok_s_fused']:.0f} prompt tok/s)",
                  flush=True)
    return rows


def run_lm_level(chunk_sizes, *, p=4, iters=8) -> list[dict]:
    """Full layer-stacked ``lm.prefill_chunk`` latency — what one packed
    engine prefill step costs end to end (embed + L scanned blocks +
    last-valid logit gather)."""
    rows = []
    cfg = bench_cfg("darkformer", m=32)
    cfg_k = dataclasses.replace(cfg, use_kernel=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    proj = lm.build_decode_proj(params, cfg_k, stacked=True)
    for l in chunk_sizes:
        state = lm.init_serve_state(cfg, b=p, max_len=2 * l,
                                    per_slot=True, stacked=True)
        toks = jnp.zeros((p, l), jnp.int32)
        vl = _ragged_lens(p, l, 0.5)
        fns = {
            "jnp": jax.jit(lambda pa, t, s, v: lm.prefill_chunk(
                pa, cfg, {"tokens": t}, s, valid_len=v)),
            "two_stage": jax.jit(lambda pa, t, s, v: lm.prefill_chunk(
                pa, cfg_k, {"tokens": t}, s, valid_len=v, fused=False)),
            "fused": jax.jit(lambda pa, t, s, v: lm.prefill_chunk(
                pa, cfg_k, {"tokens": t}, s, valid_len=v, proj=proj)),
        }
        row = {"chunk": l, "rows": p}
        for name, fn in fns.items():
            row[f"us_{name}"] = time_call(
                lambda fn=fn: fn(params, toks, state, vl)[0], iters=iters)
        row["prompt_tok_s_fused"] = int(vl.sum()) / (row["us_fused"]
                                                     * 1e-6)
        rows.append(row)
        print(f"  lm   chunk={l}: jnp={row['us_jnp']:.0f}us "
              f"two-stage={row['us_two_stage']:.0f}us "
              f"fused={row['us_fused']:.0f}us "
              f"({row['prompt_tok_s_fused']:.0f} prompt tok/s)",
              flush=True)
    return rows


def validate(payload: dict, require_win: bool = True) -> list[str]:
    """Schema check keeping the perf trajectory machine-readable.
    Returns a list of problems (empty == valid). ``require_win`` also
    enforces the ISSUE-5 acceptance bar (fused >= two-stage throughput
    at EVERY measured chunk size) — on for tracked snapshots, off for
    noisy CI smoke machines where only the schema is the contract."""
    errs = []
    if payload.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version != {SCHEMA_VERSION}")
    meth = payload.get("methodology", {})
    for key in ("backend", "kernel_mode", "timing"):
        if not isinstance(meth.get(key), str):
            errs.append(f"methodology.{key} missing")
    for section, req in (("attention", REQUIRED_ROW_KEYS),
                         ("lm_prefill", REQUIRED_LM_KEYS)):
        rows = payload.get(section)
        if not isinstance(rows, list) or not rows:
            errs.append(f"{section}: missing/empty rows")
            continue
        for row in rows:
            for key in req:
                if not isinstance(row.get(key), (int, float)):
                    errs.append(f"{section}: row {row.get('chunk')} "
                                f"lacks numeric {key!r}")
    if require_win:
        losses = [r for r in payload.get("attention", [])
                  if isinstance(r.get("fused_speedup_vs_two_stage"),
                                (int, float))
                  and r["fused_speedup_vs_two_stage"] < 1.0]
        if losses:
            errs.append(
                "fused must be >= two-stage throughput at every measured "
                "chunk size (acceptance bar of ISSUE 5); losing rows: "
                + ", ".join(f"chunk={r['chunk']} ragged="
                            f"{r['ragged_frac']}" for r in losses))
    return errs


def run(fast: bool = True) -> dict:
    chunk_sizes = (16, 64, 256) if fast else (16, 64, 256, 512)
    lm_sizes = (16, 64) if fast else (16, 64, 256)
    payload = {
        "schema_version": SCHEMA_VERSION,
        "methodology": {
            "backend": jax.default_backend(),
            "kernel_mode": ("interpret" if jax.default_backend() != "tpu"
                            else "mosaic"),
            "timing": "median wall time over warm jit calls "
                      "(benchmarks.common.time_call); one packed (P, L) "
                      "prefill chunk per call",
            "geometry": "attention: P=8 G=1 Hg=4 d=16 m=32 darkformer, "
                        "ragged mixes 0%/50% of rows cut; "
                        "lm: benchmarks.common.bench_cfg "
                        "(4L d64 m=32, layer-stacked, P=4, 50% ragged)",
            "note": "CPU interpret-mode numbers — relative ordering is "
                    "the tracked claim, absolute us are simulation-level",
        },
        "attention": run_attention_level(chunk_sizes,
                                         iters=16 if fast else 30),
        "lm_prefill": run_lm_level(lm_sizes, iters=6 if fast else 12),
    }
    errs = validate(payload)
    if errs:
        raise SystemExit("BENCH_prefill schema invalid: "
                         + "; ".join(errs))
    # benchmarks.run keys its cache (and CSV line) off the bench name
    biggest = payload["attention"][-1]
    payload["us_per_call"] = biggest["us_fused"]
    payload["derived"] = biggest["fused_speedup_vs_two_stage"]
    save_result("prefill_hotpath", payload)
    path = save_result("BENCH_prefill", payload)
    print(f"wrote {path}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny chunk sizes / few iters (CI bench-smoke)")
    ap.add_argument("--full", action="store_true",
                    help="add the 512-token chunk cell")
    ap.add_argument("--validate", action="store_true",
                    help="only validate the committed snapshot's schema")
    args = ap.parse_args()
    if args.validate:
        payload = load_result("BENCH_prefill")
        if payload is None:
            raise SystemExit("no BENCH_prefill.json snapshot to validate")
        errs = validate(payload)
        if errs:
            raise SystemExit("invalid snapshot: " + "; ".join(errs))
        print("BENCH_prefill.json schema OK "
              f"({len(payload['attention'])} attention rows, "
              f"{len(payload['lm_prefill'])} lm rows)")
        return
    if args.smoke:
        payload = {
            "schema_version": SCHEMA_VERSION,
            "methodology": {
                "backend": jax.default_backend(),
                "kernel_mode": "interpret",
                "timing": "smoke run (CI)",
            },
            "attention": run_attention_level((8, 16), p=4, iters=4,
                                             ragged_fracs=(0.5,)),
            "lm_prefill": run_lm_level((8,), p=2, iters=3),
        }
        errs = validate(payload, require_win=False)
        if errs:
            raise SystemExit("smoke schema invalid: " + "; ".join(errs))
        print("bench smoke OK")
        return
    run(fast=not args.full)


if __name__ == "__main__":
    sys.exit(main())
