"""Paper §3 (Lemma 3.1 / Thm 3.2): expected MC variance, isotropic vs the
optimal data-aligned proposal Sigma*, as anisotropy grows. Closed-form
inner expectation, MC over (q,k). Also checks the whitened-kernel variance
(DARKFormer's unweighted estimator of its data-aligned kernel)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import variance as vr
from benchmarks.common import save_result, time_call


def run(fast: bool = True) -> dict:
    d = 16
    rows = []
    key = jax.random.PRNGKey(0)
    for spread in (0.0, 0.3, 0.6, 0.8, 0.95):
        # eigenvalues in [lo, hi] with mean ~0.22, growing spread
        lo, hi = 0.22 * (1 - spread), 0.22 * (1 + spread * 1.2)
        evals = jnp.linspace(lo, hi, d)
        q, _ = jnp.linalg.qr(jax.random.normal(key, (d, d)))
        lam = (q * evals) @ q.T
        star = vr.optimal_sigma_star(lam)
        v_iso = float(vr.expected_variance(jax.random.PRNGKey(1), lam,
                                           None, n_pairs=2048))
        v_star = float(vr.expected_variance(jax.random.PRNGKey(1), lam,
                                            star, n_pairs=2048))
        rows.append({"spread": float(spread), "var_iso": v_iso,
                     "var_star": v_star,
                     "ratio": v_star / max(v_iso, 1e-30)})
    us = time_call(jax.jit(lambda k: vr.expected_variance(k, lam, star,
                                                          n_pairs=2048)),
                   jax.random.PRNGKey(2))
    out = {"rows": rows, "us_per_call": us,
           "derived": rows[-1]["ratio"]}       # variance ratio @ worst case
    save_result("variance", out)
    return out


if __name__ == "__main__":
    r = run()
    for row in r["rows"]:
        print(row)
