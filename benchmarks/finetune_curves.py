"""Paper Fig. 2 (bottom): finetuning from a pretrained exact-attention
model — the paper's primary regime. Pretrained weights fix an anisotropic
q/k geometry; each PRF kernel then finetunes from the same checkpoint.
DARKFormer gets its covariance from a small calibration batch (whitening
init, App. C) and learns it; Performer/LFK use isotropic draws."""
from __future__ import annotations

import jax

from repro.data import SyntheticLM
from repro.models import lm
from repro.core.calibration import anisotropy_score
from benchmarks.common import (bench_cfg, train, transplant, save_result,
                               SEQ, BATCH)

KERNELS = ("exact", "darkformer", "performer", "lfk", "random", "constant")


def pretrain_base(fast: bool = True, steps: int = None):
    steps = steps or (400 if fast else 2000)
    cfg = bench_cfg("exact")
    params, hist = train(cfg, steps, lr=3e-3, seed=0)
    return cfg, params, hist


def run(fast: bool = True, ft_steps: int = None, base=None) -> dict:
    ft_steps = ft_steps or (250 if fast else 1200)
    cfg_e, p_exact, hist_pre = base or pretrain_base(fast)
    # measure pretrained q/k anisotropy (the paper's premise)
    data = SyntheticLM(cfg_e.vocab, SEQ, BATCH, seed=7)
    taps = lm.collect_qk(p_exact, cfg_e, dict(data.batch(99_999)))
    q0, _ = taps["unit0/b0"]
    aniso = float(anisotropy_score(q0.reshape(-1, q0.shape[-1])))
    print(f"  pretrained q anisotropy score: {aniso:.3f}", flush=True)
    curves = {}
    for kernel in KERNELS:
        cfg = bench_cfg(kernel)
        params = transplant(p_exact, lm.init_params(
            jax.random.PRNGKey(1), cfg))
        if kernel == "darkformer":
            params = lm.whitening_calibrate(params, cfg,
                                            dict(data.batch(99_998)))
        _, hist = train(cfg, ft_steps, lr=1e-3, seed=1, params=params,
                        warmup=10)
        curves[kernel] = hist
        print(f"  finetune[{kernel}]: final eval_acc="
              f"{hist[-1]['eval_accuracy']:.4f}", flush=True)
    final = {k: v[-1]["eval_accuracy"] for k, v in curves.items()}
    gap_perf = final["exact"] - final["performer"]
    gap_dark = final["exact"] - final["darkformer"]
    closed = 1.0 - gap_dark / gap_perf if abs(gap_perf) > 1e-9 else 0.0
    out = {"curves": curves, "final": final, "gap_closed": closed,
           "anisotropy": aniso, "pretrain_hist": hist_pre,
           "us_per_call": 0.0, "derived": closed}
    save_result("finetune_curves", out)
    return out


if __name__ == "__main__":
    r = run()
    print("final:", {k: round(v, 4) for k, v in r["final"].items()})
    print("gap closed by darkformer:", round(r["gap_closed"], 3))
