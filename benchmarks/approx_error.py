"""Paper §3-4: kernel + attention approximation error vs feature budget m.

On anisotropic Gaussian q/k (eigenvalues < 1/2 so Sigma* exists) we compare
unbiased estimators of the SAME standard softmax kernel exp(q.k):

  iso      — Performer: omega ~ N(0, I)
  is_star  — importance-sampled PRF: omega ~ N(0, Sigma*), weights
             w = p_I/psi* folded in as sqrt(w) (Lemma 3.1's optimal)
  is_lam   — milder data-aligned proposal N(0, I + Lambda)

Two error metrics per m:
  * kernel_mse   — E[(kappa_hat - kappa)^2], EXACTLY Lemma 3.1's objective.
    Sigma* wins by ~4-8x and the margin grows with anisotropy (validates
    Thm 3.2 empirically).
  * attn_err     — attention-level |error|. Explicit IS weights do NOT
    transfer the win (weight degeneracy + the ratio estimator cares about
    RELATIVE kernel error, which psi* deprioritizes). This reproduces the
    paper's own motivation for DARKFormer: realize the data-aligned
    geometry through a LEARNED kernel with the unweighted estimator rather
    than explicit per-sample weights (§4, Prop 4.1). See EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import variance as vr
from benchmarks.common import save_result


def _prf_attention(q, k, v, omegas, weights=None, eps=1e-8):
    """Noncausal PRF attention from explicit draws. q,k: (B, L, d)."""
    logq = q @ omegas.T - 0.5 * jnp.sum(q * q, -1, keepdims=True)
    logk = k @ omegas.T - 0.5 * jnp.sum(k * k, -1, keepdims=True)
    c = jnp.maximum(jnp.max(logq, axis=(-2, -1), keepdims=True),
                    jnp.max(logk, axis=(-2, -1), keepdims=True))
    qf = jnp.exp(logq - c)
    kf = jnp.exp(logk - c)
    if weights is not None:
        sw = jnp.sqrt(weights)[None, None, :]
        qf = qf * sw
        kf = kf * sw
    kv = jnp.einsum("blm,bld->bmd", kf, v)
    num = jnp.einsum("blm,bmd->bld", qf, kv)
    den = jnp.einsum("blm,bm->bl", qf, jnp.sum(kf, axis=1))
    return num / (den[..., None] + eps)


def _exact_attention(q, k, v):
    logits = jnp.einsum("bqd,bkd->bqk", q, k)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def _exact_attention_causal(q, k, v):
    logits = jnp.einsum("bqd,bkd->bqk", q, k)
    l = q.shape[1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    logits = jnp.where(mask[None], logits, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def prefill_path_rows(q, k, v, key, ms=(32, 128), n_seeds=8):
    """Approximation error of the CAUSAL serving prefill path, per
    implementation: the same iso-PRF estimator routed through the jnp
    resume path, the two-stage Pallas path (jnp featmap + carry-scan
    kernel) and the fused ``prf_fused_prefill`` megakernel, all against
    causal exact attention. The tracked claim is that the fused path
    changes NOTHING about the estimator — its attention error matches
    the legacy paths to f32 noise (``max_dev_fused_vs_two_stage``),
    so the per-m error budget of §3-4 transfers to the new kernel.
    """
    from repro.core import attention as rfa
    from repro.core import feature_maps as fm
    import numpy as np
    b, l, d = q.shape
    # rf_attention_prefill absorbs a d^{-1/4} temperature per side;
    # pre-scale so the estimator still targets exp(q.k) like the rest
    # of this benchmark
    qs = (q * d ** 0.25)[:, None, None]              # (B, 1, 1, L, d)
    ks = (k * d ** 0.25)[:, None, None]
    vs = v[:, None, None]
    exact = _exact_attention_causal(q, k, v)
    rows = []
    for m in ms:
        errs = {"jnp": [], "two_stage": [], "fused": []}
        devs = []
        for s in range(n_seeds):
            w = jax.random.normal(jax.random.fold_in(key, 1000 * m + s),
                                  (1, m, d))
            fparams = {"w": w}
            cfg = fm.FeatureConfig(kind="performer", num_features=m)
            proj = fm.precompose_projection(fparams, cfg.kind)
            outs = {}
            for name, kw in (("jnp", {}),
                             ("two_stage", {"use_kernel": True}),
                             ("fused", {"use_kernel": True,
                                        "proj": proj})):
                st = rfa.init_linear_serve_state(b, 1, 1, m, d)
                o, _ = rfa.rf_attention_prefill(qs, ks, vs, fparams, cfg,
                                                state=st, **kw)
                outs[name] = o[:, 0, 0]
                errs[name].append(float(jnp.mean(jnp.abs(outs[name]
                                                         - exact))))
            devs.append(float(jnp.max(jnp.abs(outs["fused"]
                                              - outs["two_stage"]))))
        rows.append({
            "m": m,
            "attn_err_jnp": float(np.median(errs["jnp"])),
            "attn_err_two_stage": float(np.median(errs["two_stage"])),
            "attn_err_fused": float(np.median(errs["fused"])),
            "max_dev_fused_vs_two_stage": float(np.max(devs)),
        })
    return rows


def run(fast: bool = True) -> dict:
    key = jax.random.PRNGKey(3)
    B, L, d = 4, 64, 16
    # anisotropic Lambda with eigenvalues in (0.03, 0.45): Sigma* exists
    evals = jnp.exp(jnp.linspace(jnp.log(0.35), jnp.log(0.02), d))
    rot, _ = jnp.linalg.qr(jax.random.normal(key, (d, d)))
    lam = (rot * evals) @ rot.T
    chol = jnp.linalg.cholesky(lam)
    kq, kk, kv = jax.random.split(jax.random.fold_in(key, 1), 3)
    q = jax.random.normal(kq, (B, L, d)) @ chol.T
    k = jax.random.normal(kk, (B, L, d)) @ chol.T
    v = jax.random.normal(kv, (B, L, d))
    exact = _exact_attention(q, k, v)
    star = vr.optimal_sigma_star(lam)
    chol_star = jnp.linalg.cholesky(star)
    lam_prop = jnp.eye(d) + lam
    chol_lam = jnp.linalg.cholesky(lam_prop)

    qf2 = q.reshape(-1, d)
    kf2 = k.reshape(-1, d)
    true_kernel = jnp.exp(jnp.sum(qf2 * kf2, -1))

    def one(mfeat, seed):
        kw = jax.random.PRNGKey(seed)
        g = jax.random.normal(kw, (mfeat, d))
        om_star = g @ chol_star.T
        w_star = 1.0 / vr.importance_weight(om_star, star)
        om_lam = g @ chol_lam.T
        w_lam = 1.0 / vr.importance_weight(om_lam, lam_prop)
        # kernel-level MSE (Lemma 3.1's objective)
        mse = lambda est: float(jnp.mean((est - true_kernel) ** 2))
        k_iso = mse(vr.mc_kernel_estimate(qf2, kf2, g))
        k_star = mse(vr.mc_kernel_estimate(qf2, kf2, om_star, w_star))
        k_lam = mse(vr.mc_kernel_estimate(qf2, kf2, om_lam, w_lam))
        # attention-level error
        err = lambda om, w=None: float(jnp.mean(jnp.abs(
            _prf_attention(q, k, v, om, w) - exact)))
        return (k_iso, k_star, k_lam, err(g), err(om_star, w_star),
                err(om_lam, w_lam))

    rows = []
    n_seeds = 16 if fast else 48
    import numpy as np
    for m in (8, 16, 32, 64, 128, 256):
        es = [one(m, 100 + s) for s in range(n_seeds)]
        # median over seeds: the MSE of a heavy-tailed error is itself
        # heavy-tailed; medians make the comparison stable at bench scale
        agg = [float(np.median([e[i] for e in es])) for i in range(6)]
        rows.append({"m": m,
                     "kernel_mse_iso": agg[0], "kernel_mse_star": agg[1],
                     "kernel_mse_lam": agg[2],
                     "attn_err_iso": agg[3], "attn_err_star": agg[4],
                     "attn_err_lam": agg[5],
                     "kernel_ratio_star": agg[1] / max(agg[0], 1e-12)})
    # causal serving-path coverage: the fused prefill megakernel must
    # carry the same approximation error as the legacy paths
    prefill_rows = prefill_path_rows(q, k, v, jax.random.fold_in(key, 2),
                                     n_seeds=8 if fast else 24)
    for row in prefill_rows:
        print(f"  prefill-path m={row['m']}: "
              f"err jnp={row['attn_err_jnp']:.4f} "
              f"two-stage={row['attn_err_two_stage']:.4f} "
              f"fused={row['attn_err_fused']:.4f} "
              f"(fused vs two-stage dev "
              f"{row['max_dev_fused_vs_two_stage']:.2e})", flush=True)
    out = {"rows": rows, "prefill_path_rows": prefill_rows,
           "us_per_call": 0.0,
           "derived": rows[-1]["kernel_ratio_star"]}  # MSE ratio @ m=256
    save_result("approx_error", out)
    return out


if __name__ == "__main__":
    for row in run()["rows"]:
        print({k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in row.items()})
