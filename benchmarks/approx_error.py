"""Paper §3-4: kernel + attention approximation error vs feature budget m.

On anisotropic Gaussian q/k (eigenvalues < 1/2 so Sigma* exists) we compare
unbiased estimators of the SAME standard softmax kernel exp(q.k):

  iso      — Performer: omega ~ N(0, I)
  is_star  — importance-sampled PRF: omega ~ N(0, Sigma*), weights
             w = p_I/psi* folded in as sqrt(w) (Lemma 3.1's optimal)
  is_lam   — milder data-aligned proposal N(0, I + Lambda)

Two error metrics per m:
  * kernel_mse   — E[(kappa_hat - kappa)^2], EXACTLY Lemma 3.1's objective.
    Sigma* wins by ~4-8x and the margin grows with anisotropy (validates
    Thm 3.2 empirically).
  * attn_err     — attention-level |error|. Explicit IS weights do NOT
    transfer the win (weight degeneracy + the ratio estimator cares about
    RELATIVE kernel error, which psi* deprioritizes). This reproduces the
    paper's own motivation for DARKFormer: realize the data-aligned
    geometry through a LEARNED kernel with the unweighted estimator rather
    than explicit per-sample weights (§4, Prop 4.1). See EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import variance as vr
from benchmarks.common import save_result


def _prf_attention(q, k, v, omegas, weights=None, eps=1e-8):
    """Noncausal PRF attention from explicit draws. q,k: (B, L, d)."""
    logq = q @ omegas.T - 0.5 * jnp.sum(q * q, -1, keepdims=True)
    logk = k @ omegas.T - 0.5 * jnp.sum(k * k, -1, keepdims=True)
    c = jnp.maximum(jnp.max(logq, axis=(-2, -1), keepdims=True),
                    jnp.max(logk, axis=(-2, -1), keepdims=True))
    qf = jnp.exp(logq - c)
    kf = jnp.exp(logk - c)
    if weights is not None:
        sw = jnp.sqrt(weights)[None, None, :]
        qf = qf * sw
        kf = kf * sw
    kv = jnp.einsum("blm,bld->bmd", kf, v)
    num = jnp.einsum("blm,bmd->bld", qf, kv)
    den = jnp.einsum("blm,bm->bl", qf, jnp.sum(kf, axis=1))
    return num / (den[..., None] + eps)


def _exact_attention(q, k, v):
    logits = jnp.einsum("bqd,bkd->bqk", q, k)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def run(fast: bool = True) -> dict:
    key = jax.random.PRNGKey(3)
    B, L, d = 4, 64, 16
    # anisotropic Lambda with eigenvalues in (0.03, 0.45): Sigma* exists
    evals = jnp.exp(jnp.linspace(jnp.log(0.35), jnp.log(0.02), d))
    rot, _ = jnp.linalg.qr(jax.random.normal(key, (d, d)))
    lam = (rot * evals) @ rot.T
    chol = jnp.linalg.cholesky(lam)
    kq, kk, kv = jax.random.split(jax.random.fold_in(key, 1), 3)
    q = jax.random.normal(kq, (B, L, d)) @ chol.T
    k = jax.random.normal(kk, (B, L, d)) @ chol.T
    v = jax.random.normal(kv, (B, L, d))
    exact = _exact_attention(q, k, v)
    star = vr.optimal_sigma_star(lam)
    chol_star = jnp.linalg.cholesky(star)
    lam_prop = jnp.eye(d) + lam
    chol_lam = jnp.linalg.cholesky(lam_prop)

    qf2 = q.reshape(-1, d)
    kf2 = k.reshape(-1, d)
    true_kernel = jnp.exp(jnp.sum(qf2 * kf2, -1))

    def one(mfeat, seed):
        kw = jax.random.PRNGKey(seed)
        g = jax.random.normal(kw, (mfeat, d))
        om_star = g @ chol_star.T
        w_star = 1.0 / vr.importance_weight(om_star, star)
        om_lam = g @ chol_lam.T
        w_lam = 1.0 / vr.importance_weight(om_lam, lam_prop)
        # kernel-level MSE (Lemma 3.1's objective)
        mse = lambda est: float(jnp.mean((est - true_kernel) ** 2))
        k_iso = mse(vr.mc_kernel_estimate(qf2, kf2, g))
        k_star = mse(vr.mc_kernel_estimate(qf2, kf2, om_star, w_star))
        k_lam = mse(vr.mc_kernel_estimate(qf2, kf2, om_lam, w_lam))
        # attention-level error
        err = lambda om, w=None: float(jnp.mean(jnp.abs(
            _prf_attention(q, k, v, om, w) - exact)))
        return (k_iso, k_star, k_lam, err(g), err(om_star, w_star),
                err(om_lam, w_lam))

    rows = []
    n_seeds = 16 if fast else 48
    import numpy as np
    for m in (8, 16, 32, 64, 128, 256):
        es = [one(m, 100 + s) for s in range(n_seeds)]
        # median over seeds: the MSE of a heavy-tailed error is itself
        # heavy-tailed; medians make the comparison stable at bench scale
        agg = [float(np.median([e[i] for e in es])) for i in range(6)]
        rows.append({"m": m,
                     "kernel_mse_iso": agg[0], "kernel_mse_star": agg[1],
                     "kernel_mse_lam": agg[2],
                     "attn_err_iso": agg[3], "attn_err_star": agg[4],
                     "attn_err_lam": agg[5],
                     "kernel_ratio_star": agg[1] / max(agg[0], 1e-12)})
    out = {"rows": rows, "us_per_call": 0.0,
           "derived": rows[-1]["kernel_ratio_star"]}  # MSE ratio @ m=256
    save_result("approx_error", out)
    return out


if __name__ == "__main__":
    for row in run()["rows"]:
        print({k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in row.items()})
