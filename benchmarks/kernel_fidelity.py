"""Kernel swap fidelity on REAL pretrained activations (Fig. 2-bottom @
step 0, and the paper's central mechanism).

Pretrain the bench model with exact attention (its q/k become naturally
anisotropic — we report the measured anisotropy score), then swap in each
PRF kernel WITHOUT any finetuning and measure, per feature budget m:

  * attention-output error of layer 0 vs the exact model's attention
    (MC estimator quality on real activations, the Lemma 3.1 quantity);
  * logit KL(exact || approx) and eval-loss delta (downstream damage).

DARKFormer uses the whitening-calibrated covariance (M = Lambda^{-1/2}
from one calibration batch, App. C); Performer/LFK are isotropic draws.
This isolates the paper's claim — data-aligned sampling needs fewer
features — from optimizer/task effects that a 1-CPU-core training run
cannot resolve (see EXPERIMENTS.md §Training).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.calibration import anisotropy_score
from repro.data import SyntheticLM
from repro.models import lm
from repro.launch import steps as steps_lib
from benchmarks.common import (bench_cfg, train, transplant, save_result,
                               SEQ, BATCH)
from benchmarks.finetune_curves import pretrain_base


def _swap_metrics(cfg_e, p_exact, kernel, m, data, calib_batch, n_eval=4):
    import dataclasses
    cfg = bench_cfg(kernel, m=m)
    params = transplant(p_exact, lm.init_params(jax.random.PRNGKey(2), cfg))
    if kernel == "darkformer":
        params = lm.whitening_calibrate(params, cfg, calib_batch)
    eval_fn = jax.jit(steps_lib.make_eval_step(cfg))
    kl_total, loss_total = 0.0, 0.0
    for i in range(n_eval):
        batch = dict(data.batch(50_000 + i))
        logits_e, _ = lm.forward_train(p_exact, cfg_e, batch)
        logits_a, _ = lm.forward_train(params, cfg, batch)
        pe = jax.nn.log_softmax(logits_e, -1)
        pa = jax.nn.log_softmax(logits_a, -1)
        kl = jnp.sum(jnp.exp(pe) * (pe - pa), -1)
        kl_total += float(jnp.mean(kl))
        loss_total += float(eval_fn(params, batch)["ce"])
    return kl_total / n_eval, loss_total / n_eval


def _anisotropize(p_exact, cfg_e, strength=2.5):
    """Surgically inject per-head anisotropy into every wq/wk (exp-decaying
    spectrum over head_dim) — reproducing at bench scale the anisotropic
    q/k statistics that Godey et al. observe in real pretrained LMs (the
    paper's premise), which a 4-layer synthetic-data model does not
    develop on its own (measured score 0.019)."""
    dh = cfg_e.head_dim
    scale = jnp.exp(jnp.linspace(strength / 2, -strength / 2, dh))

    def mod(path, leaf):
        ps = jax.tree_util.keystr(path)
        if ps.endswith("['wq']") or ps.endswith("['wk']"):
            out = leaf.reshape(*leaf.shape[:-1], -1, dh) * scale
            return out.reshape(leaf.shape)
        return leaf
    flat, tdef = jax.tree_util.tree_flatten_with_path(p_exact)
    return jax.tree_util.tree_unflatten(tdef, [mod(p, l) for p, l in flat])


def learn_m_experiment(cfg_e, p_exact, data, steps=160, m=12, lr=2e-3):
    """The paper's central mechanism, isolated: swap exact -> PRF with
    M = I (dark == performer bit-for-bit at init), finetune briefly; ONLY
    darkformer can adapt M (performer's W is a frozen draw), so any gap is
    purely the learned sampling geometry. Run on the anisotropized model
    where the geometry matters."""
    out = {}
    for kernel in ("darkformer", "performer"):
        cfg = bench_cfg(kernel, m=m)
        params = transplant(p_exact, lm.init_params(
            jax.random.PRNGKey(2), cfg))
        _, hist = train(cfg, steps, lr=lr, seed=3, params=params,
                        warmup=10, record_every=20, data=data,
                        eval_batches=2)
        out[kernel] = hist
    return out


def prefill_path_fidelity(cfg_e, p_exact, data, calib_batch, m=16,
                          n_eval=2):
    """Swap fidelity THROUGH THE SERVING PREFILL PATHS: last-position
    logit KL(exact || approx) of the whitening-calibrated darkformer
    swap, with the swap model's logits produced by ``lm.prefill`` via
    the jnp resume path, the two-stage kernel path, and the fused
    ``prf_fused_prefill`` megakernel. The fused path must carry the
    SAME fidelity as the legacy ones (``max_dev_fused_vs_jnp`` is f32
    noise) — approximation-error tracking covers the path the engine
    actually serves, not just the training-time attention."""
    import dataclasses
    cfg = bench_cfg("darkformer", m=m)
    params = transplant(p_exact, lm.init_params(jax.random.PRNGKey(2),
                                                cfg))
    params = lm.whitening_calibrate(params, cfg, calib_batch)
    cfg_k = dataclasses.replace(cfg, use_kernel=True)
    out = {"m": m}
    kls = {}
    devs = []
    for i in range(n_eval):
        batch = dict(data.batch(60_000 + i))
        toks = batch["tokens"]
        logits_e, _ = lm.prefill(p_exact, cfg_e, {"tokens": toks},
                                 max_len=toks.shape[1] + 1)
        pe = jax.nn.log_softmax(logits_e[:, -1], -1)
        lgs = {}
        for name, (c, kw) in (("jnp", (cfg, {})),
                              ("two_stage", (cfg_k, {"fused": False})),
                              ("fused", (cfg_k, {}))):
            st = lm.init_serve_state(cfg, b=toks.shape[0],
                                     max_len=toks.shape[1] + 1,
                                     per_slot=True, stacked=True)
            lg, _ = lm.prefill_chunk(params, c, {"tokens": toks}, st,
                                     **kw)
            lgs[name] = lg
            pa = jax.nn.log_softmax(lg, -1)
            kls.setdefault(name, []).append(
                float(jnp.mean(jnp.sum(jnp.exp(pe) * (pe - pa), -1))))
        devs.append(float(jnp.max(jnp.abs(lgs["fused"] - lgs["jnp"]))))
    for name, vals in kls.items():
        out[f"kl_{name}"] = sum(vals) / len(vals)
    out["max_dev_fused_vs_jnp"] = max(devs)
    return out


def run(fast: bool = True, base=None) -> dict:
    cfg_e, p_exact, _ = base or pretrain_base(fast)
    data = SyntheticLM(cfg_e.vocab, SEQ, BATCH, seed=7, host=13)
    calib = dict(SyntheticLM(cfg_e.vocab, SEQ, BATCH, seed=7).batch(99_998))
    taps = lm.collect_qk(p_exact, cfg_e, calib)
    q0, k0 = taps["unit0/b0"]
    aniso = float(anisotropy_score(q0.reshape(-1, q0.shape[-1])))
    eval_fn = jax.jit(steps_lib.make_eval_step(cfg_e))
    ce_exact = sum(float(eval_fn(p_exact, dict(data.batch(50_000 + i)))
                         ["ce"]) for i in range(4)) / 4
    rows = []
    for m in (8, 16, 32, 64):
        row = {"m": m}
        for kernel in ("darkformer", "performer", "lfk"):
            kl, ce = _swap_metrics(cfg_e, p_exact, kernel, m, data, calib)
            row[f"kl_{kernel}"] = kl
            row[f"ce_{kernel}"] = ce
        row["kl_ratio"] = row["kl_darkformer"] / max(row["kl_performer"],
                                                     1e-12)
        rows.append(row)
        print(f"  fidelity m={m}: KL dark={row['kl_darkformer']:.4f} "
              f"perf={row['kl_performer']:.4f} "
              f"ratio={row['kl_ratio']:.3f}", flush=True)
    # --- serving-path coverage: the fused prefill megakernel must not
    # change the swap fidelity ---
    ppath = prefill_path_fidelity(cfg_e, p_exact, data, calib)
    print(f"  prefill-path m={ppath['m']}: KL jnp={ppath['kl_jnp']:.4f} "
          f"two-stage={ppath['kl_two_stage']:.4f} "
          f"fused={ppath['kl_fused']:.4f} "
          f"(fused vs jnp dev {ppath['max_dev_fused_vs_jnp']:.2e})",
          flush=True)
    # --- the mechanism demo on an anisotropized model ---
    p_aniso = _anisotropize(p_exact, cfg_e)
    taps_a = lm.collect_qk(p_aniso, cfg_e, calib)
    qa, _ = taps_a["unit0/b0"]
    aniso_inj = float(anisotropy_score(qa.reshape(-1, qa.shape[-1])))
    curves = learn_m_experiment(cfg_e, p_aniso, 
                                SyntheticLM(cfg_e.vocab, SEQ, BATCH,
                                            seed=7))
    final_dark = curves["darkformer"][-1]["loss"]
    final_perf = curves["performer"][-1]["loss"]
    print(f"  learn-M (injected aniso {aniso_inj:.3f}): "
          f"dark loss={final_dark:.4f} perf loss={final_perf:.4f}",
          flush=True)
    out = {"rows": rows, "prefill_path": ppath, "anisotropy": aniso,
           "anisotropy_injected": aniso_inj, "ce_exact": ce_exact,
           "learn_m_curves": curves,
           "learn_m_gap": final_perf - final_dark,
           "us_per_call": 0.0,
           "derived": final_perf - final_dark}   # dark advantage (loss)
    save_result("kernel_fidelity", out)
    return out


if __name__ == "__main__":
    r = run()
    print("pretrained q anisotropy:", round(r["anisotropy"], 3))
    for row in r["rows"]:
        print({k: round(v, 4) for k, v in row.items()})
