"""Benchmark driver — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Each benchmark caches its payload
under experiments/bench/<name>.json; pass --force to recompute, --full for
the long (paper-scale-down) versions. The roofline rows come from the
dry-run artifacts (run ``python -m repro.launch.dryrun --all [--probe]``
first; this repo ships the cached results).

  variance         Thm 3.2: E[Var] ratio Sigma*/isotropic @ max anisotropy
  approx_error     Lemma 3.1 at kernel+attention level vs feature budget
  kernel_fidelity  kernel swap on real pretrained activations (KL vs m)
  pretrain_curves  Fig 2 top: 6 kernels from scratch (gap closed)
  finetune_curves  Fig 2 bottom: finetune from exact-attn checkpoint
  finetune_long    Fig 3: long-cycle finetune (early vs late gap)
  finetune_limited Fig 4: q/k/v + covariance-only finetune
  lr_stability     Fig 5: loss spikes across LR sweep (perf - dark)
  attn_scaling     Fig 1: exact vs linear attention wall time
  serve_latency    O(1)-state decode vs KV decode across context lengths
  serve_faults     kernel-ladder stream equality + health probe + recovery
  decode_hotpath   fused decode megakernel vs two-kernel vs jnp per-token
  prefill_hotpath  fused prefill megakernel vs two-stage vs jnp per-chunk
  roofline_*       §Roofline: worst train-cell roofline fraction
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

from benchmarks import common

BENCHES = ("variance", "approx_error", "kernel_fidelity",
           "pretrain_curves",
           "finetune_curves", "finetune_long", "finetune_limited",
           "lr_stability", "attn_scaling", "serve_latency",
           "serve_faults", "decode_hotpath", "prefill_hotpath",
           "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="long versions (hours on CPU)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else BENCHES
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            cached = None if (args.force or args.full) else \
                common.load_result(name)
            if cached is not None:
                out = cached
            else:
                mod = importlib.import_module(f"benchmarks.{name}")
                out = mod.run(fast=not args.full)
            print(f"{name},{out.get('us_per_call', 0.0):.1f},"
                  f"{out.get('derived', 0.0):.6g}", flush=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,ERROR:{type(e).__name__}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
