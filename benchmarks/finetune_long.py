"""Paper Fig. 3: long-cycle finetuning — Performer eventually narrows the
gap to DARKFormer (the transformer can learn to produce isotropic q/k),
but needs many more steps. Log-spaced recording."""
from __future__ import annotations

import jax

from repro.models import lm
from repro.data import SyntheticLM
from benchmarks.common import (bench_cfg, train, transplant, save_result,
                               SEQ, BATCH)
from benchmarks.finetune_curves import pretrain_base


def run(fast: bool = True, base=None) -> dict:
    steps = 800 if fast else 4000
    cfg_e, p_exact, _ = base or pretrain_base(fast)
    data = SyntheticLM(cfg_e.vocab, SEQ, BATCH, seed=7)
    curves = {}
    for kernel in ("exact", "darkformer", "performer"):
        cfg = bench_cfg(kernel)
        params = transplant(p_exact, lm.init_params(
            jax.random.PRNGKey(1), cfg))
        if kernel == "darkformer":
            params = lm.whitening_calibrate(params, cfg,
                                            dict(data.batch(99_998)))
        _, hist = train(cfg, steps, lr=1e-3, seed=1, params=params,
                        warmup=10, record_every=25)
        curves[kernel] = hist
        print(f"  long-ft[{kernel}]: final={hist[-1]['eval_accuracy']:.4f}",
              flush=True)

    def acc_at(kernel, frac):
        h = curves[kernel]
        return h[min(int(frac * (len(h) - 1)), len(h) - 1)]["eval_accuracy"]

    # gap at 25% of training vs at the end: Performer catches up late
    early_gap = acc_at("darkformer", 0.25) - acc_at("performer", 0.25)
    late_gap = acc_at("darkformer", 1.0) - acc_at("performer", 1.0)
    out = {"curves": curves, "early_gap": early_gap, "late_gap": late_gap,
           "us_per_call": 0.0, "derived": early_gap - late_gap}
    save_result("finetune_long", out)
    return out


if __name__ == "__main__":
    r = run()
    print("early gap:", round(r["early_gap"], 4),
          "late gap:", round(r["late_gap"], 4))
