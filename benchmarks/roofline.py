"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / ICI_bw_per_chip

Notes:
  * compiled.cost_analysis() on an SPMD-partitioned module reports
    PER-DEVICE flops/bytes (verified: smollm train_4k reports 3.58e12 vs
    8.5e14 global = 6ND), so no chips division is needed beyond per-chip
    peaks.
  * collective_bytes comes from summing result-shape bytes of every
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute in the optimized HLO (received-bytes
    approximation).
  * MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) global per step,
    divided by chips for the per-device "useful" figure.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3D-torus; one-link figure used, consistent across
cells so relative comparisons hold).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link / chip

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")

# active params per token (N or N_active), for MODEL_FLOPS = 6*N*D
ACTIVE_PARAMS = {
    "recurrentgemma-2b": 2.7e9,
    "smollm-135m": 1.35e8,
    "granite-8b": 8.1e9,
    "qwen3-32b": 3.28e10,
    "yi-34b": 3.44e10,
    "rwkv6-7b": 7.6e9,
    "granite-moe-3b-a800m": 8.0e8,        # a800m active
    "qwen3-moe-235b-a22b": 2.2e10,        # a22b active
    "internvl2-76b": 7.0e10,
    "hubert-xlarge": 9.6e8,
    "darkformer-2b": 2.5e9,
}


def n_chips(rec: dict) -> int:
    m = rec.get("mesh", {})
    n = 1
    for v in m.values():
        n *= v
    return n


def model_flops(rec: dict) -> float:
    """6 * N_active * tokens, global per step (train fwd+bwd). For
    prefill (fwd only) use 2*N*D; decode: 2*N_active*B tokens."""
    act = ACTIVE_PARAMS.get(rec["arch"], 0.0)
    kind = rec["kind"]
    if kind == "train":
        toks = rec["global_batch"] * rec["seq_len"]
        return 6.0 * act * toks
    if kind == "prefill":
        toks = rec["global_batch"] * rec["seq_len"]
        return 2.0 * act * toks
    toks = rec["global_batch"]          # one token per sequence
    return 2.0 * act * toks


def analyze(rec: dict, probe: Optional[dict] = None) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = n_chips(rec)
    fl = rec.get("flops", 0.0)                      # per-device
    by = rec.get("bytes_accessed", 0.0)             # per-device
    coll = rec.get("collectives", {}).get("total", 0.0)
    if probe and probe.get("status") == "ok":
        # exact scan-aware costs from the 2-point unrolled probe (XLA's
        # HloCostAnalysis counts while bodies once; see dryrun.py)
        e = probe["extrapolated"]
        fl = e["flops"]
        by = e["bytes_accessed"]
        coll = e["collective_total"]
    t_compute = fl / PEAK_FLOPS
    t_memory = by / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec) / chips                   # useful per-device
    useful = mf / fl if fl else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model flops at peak / bound time
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {"arch": rec["arch"], "shape": rec["shape"],
            "mesh": "x".join(str(v) for v in rec.get("mesh", {}).values()),
            "chips": chips,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops_ratio": useful, "roofline_frac": frac,
            "probed": bool(probe and probe.get("status") == "ok"),
            "compile_s": rec.get("compile_s")}


def load_all(outdir: str = DRYRUN_DIR, mesh: str = "pod",
             tag: str = "") -> list[dict]:
    rows = []
    suffix = f"__{mesh}" + (f"__{tag}" if tag else "") + ".json"
    for path in sorted(glob.glob(os.path.join(outdir, f"*{suffix}"))):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        with open(path) as f:
            rec = json.load(f)
        probe = None
        ppath = os.path.join(outdir, f"{parts[0]}__{parts[1]}__probe.json")
        if os.path.exists(ppath):
            with open(ppath) as f:
                probe = json.load(f)
        a = analyze(rec, probe)
        if a:
            rows.append(a)
    return rows


def fmt_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':9s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'dominant':>10s} "
           f"{'useful':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:9s} "
            f"{r['t_compute_s']:10.3e} {r['t_memory_s']:10.3e} "
            f"{r['t_collective_s']:10.3e} {r['dominant']:>10s} "
            f"{r['model_flops_ratio']:7.3f} {r['roofline_frac']:9.3f}")
    return "\n".join(lines)


def run(fast: bool = True) -> dict:
    rows = load_all(mesh="pod")
    out = {"rows": rows, "us_per_call": 0.0,
           "derived": (sorted(r["roofline_frac"] for r in rows
                              if r["shape"] == "train_4k") or [0.0])[0]}
    return out


if __name__ == "__main__":
    rows = load_all(mesh="pod")
    print(fmt_table(rows))
    print()
    rows_mp = load_all(mesh="multipod")
    print(fmt_table(rows_mp))
