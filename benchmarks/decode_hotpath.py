"""Decode hot-path bench: fused megakernel vs two-kernel vs jnp.

Measures the serving engine's steady-state unit of work — ONE batched
decode step — at several slot counts, through three implementations of
the PRF attention decode:

  * ``jnp``         — pure-jnp feature map + einsum state update
    (``rf_attention_decode(use_kernel=False)``);
  * ``two_kernel``  — the pre-ISSUE-4 Pallas path: jnp
    ``_resume_qk_features`` + the ``prf_decode_step`` state-update
    kernel, with the (N, m) feature tensors round-tripping HBM between
    them;
  * ``fused``       — the ``prf_fused_decode`` megakernel: projection,
    exp feature map with in-kernel running-max stabilizer, (S, z)
    update and readout in one kernel, pool aliased in place.

Two levels: raw attention-op latency (isolates the kernel change) and
full ``lm.decode_step`` latency / tokens/s on the reduced bench model
(includes the layer-stacked scan the engine runs). Snapshot written to
``experiments/bench/BENCH_decode.json`` with the methodology recorded —
on this CPU container the kernels run in interpret mode, so absolute
numbers are simulation-level; the RELATIVE ordering (what the
trajectory tracks) is the claim. Schema is validated on every write and
by the CI bench-smoke job (``--validate``).
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from repro.core import attention as rfa
from repro.core import feature_maps as fm
from repro.models import lm
from benchmarks.common import bench_cfg, load_result, save_result, \
    time_call

SCHEMA_VERSION = 1
REQUIRED_ROW_KEYS = ("slots", "us_jnp", "us_two_kernel", "us_fused",
                     "fused_speedup_vs_two_kernel", "tok_s_fused")
REQUIRED_LM_KEYS = ("slots", "us_jnp", "us_two_kernel", "us_fused",
                    "tok_s_fused")


def run_attention_level(slot_counts, *, g=1, hg=4, d=16, m=32,
                        iters=30) -> list[dict]:
    """Per-token latency of the attention decode op alone, three ways."""
    cfg = fm.FeatureConfig(kind="darkformer", num_features=m)
    fparams = fm.init_feature_params(jax.random.PRNGKey(0), cfg, d,
                                     n_groups=g)
    proj = fm.precompose_projection(fparams, cfg.kind)
    rows = []
    for b in slot_counts:
        state = rfa.init_linear_serve_state(b, g, hg, m, d)
        key = jax.random.PRNGKey(b)
        q = jax.random.normal(key, (b, g, hg, 1, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, g, 1, 1, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, g, 1, 1, d))

        def mk(**kw):
            return jax.jit(lambda q, k, v, s: rfa.rf_attention_decode(
                q, k, v, s, fparams, cfg, **kw))

        fns = {"jnp": mk(),
               "two_kernel": mk(use_kernel=True),
               "fused": mk(use_kernel=True, proj=proj)}
        row = {"slots": b}
        for name, fn in fns.items():
            row[f"us_{name}"] = time_call(lambda fn=fn: fn(q, k, v, state),
                                          iters=iters)
        row["fused_speedup_vs_two_kernel"] = (row["us_two_kernel"]
                                              / max(row["us_fused"], 1e-9))
        row["tok_s_fused"] = b / (row["us_fused"] * 1e-6)
        rows.append(row)
        print(f"  attn slots={b}: jnp={row['us_jnp']:.0f}us "
              f"two-kernel={row['us_two_kernel']:.0f}us "
              f"fused={row['us_fused']:.0f}us "
              f"({row['fused_speedup_vs_two_kernel']:.2f}x, "
              f"{row['tok_s_fused']:.0f} tok/s)", flush=True)
    return rows


def run_lm_level(slot_counts, *, iters=12) -> list[dict]:
    """Full layer-stacked ``lm.decode_step`` latency — what one engine
    decode step costs end to end (embed + L scanned blocks + logits)."""
    rows = []
    cfg = bench_cfg("darkformer", m=32)
    import dataclasses
    cfg_k = dataclasses.replace(cfg, use_kernel=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    proj = lm.build_decode_proj(params, cfg_k, stacked=True)
    for b in slot_counts:
        state = lm.init_serve_state(cfg, b=b, max_len=64, per_slot=True,
                                    stacked=True)
        toks = jnp.zeros((b,), jnp.int32)
        fns = {
            "jnp": jax.jit(lambda p, t, s: lm.decode_step(p, cfg, t, s)),
            "two_kernel": jax.jit(lambda p, t, s: lm.decode_step(
                p, cfg_k, t, s, fused=False)),
            "fused": jax.jit(lambda p, t, s: lm.decode_step(
                p, cfg_k, t, s, proj=proj)),
        }
        row = {"slots": b}
        for name, fn in fns.items():
            row[f"us_{name}"] = time_call(
                lambda fn=fn: fn(params, toks, state)[0], iters=iters)
        row["tok_s_fused"] = b / (row["us_fused"] * 1e-6)
        rows.append(row)
        print(f"  lm   slots={b}: jnp={row['us_jnp']:.0f}us "
              f"two-kernel={row['us_two_kernel']:.0f}us "
              f"fused={row['us_fused']:.0f}us "
              f"({row['tok_s_fused']:.0f} tok/s)", flush=True)
    return rows


def validate(payload: dict, require_win: bool = True) -> list[str]:
    """Schema check keeping the perf trajectory machine-readable.
    Returns a list of problems (empty == valid). ``require_win`` also
    enforces the ISSUE-4 acceptance bar (fused < two-kernel at >= 2
    slot counts) — on for tracked snapshots, off for noisy CI smoke
    machines where only the schema is the contract."""
    errs = []
    if payload.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version != {SCHEMA_VERSION}")
    meth = payload.get("methodology", {})
    for key in ("backend", "kernel_mode", "timing"):
        if not isinstance(meth.get(key), str):
            errs.append(f"methodology.{key} missing")
    for section, req in (("attention", REQUIRED_ROW_KEYS),
                         ("lm_decode", REQUIRED_LM_KEYS)):
        rows = payload.get(section)
        if not isinstance(rows, list) or not rows:
            errs.append(f"{section}: missing/empty rows")
            continue
        for row in rows:
            for key in req:
                if not isinstance(row.get(key), (int, float)):
                    errs.append(f"{section}: row {row.get('slots')} "
                                f"lacks numeric {key!r}")
    if require_win:
        wins = [r for r in payload.get("attention", [])
                if isinstance(r.get("fused_speedup_vs_two_kernel"),
                              (int, float))
                and r["fused_speedup_vs_two_kernel"] > 1.0]
        if len(wins) < 2:
            errs.append("fused must beat the two-kernel path at >= 2 "
                        "slot counts (acceptance bar of ISSUE 4)")
    return errs


def run(fast: bool = True) -> dict:
    slot_counts = (4, 16, 64) if fast else (4, 16, 64, 256)
    lm_counts = (2, 8) if fast else (2, 8, 32)
    payload = {
        "schema_version": SCHEMA_VERSION,
        "methodology": {
            "backend": jax.default_backend(),
            "kernel_mode": ("interpret" if jax.default_backend() != "tpu"
                            else "mosaic"),
            "timing": "median wall time over warm jit calls "
                      "(benchmarks.common.time_call); one batched decode "
                      "step per call",
            "geometry": "attention: G=1 Hg=4 d=16 m=32 darkformer; "
                        "lm: benchmarks.common.bench_cfg "
                        "(4L d64 m=32, layer-stacked decode)",
            "note": "CPU interpret-mode numbers — relative ordering is "
                    "the tracked claim, absolute us are simulation-level",
        },
        "attention": run_attention_level(slot_counts,
                                         iters=30 if fast else 50),
        "lm_decode": run_lm_level(lm_counts, iters=10 if fast else 20),
    }
    errs = validate(payload)
    if errs:
        raise SystemExit("BENCH_decode schema invalid: " + "; ".join(errs))
    # benchmarks.run keys its cache (and CSV line) off the bench name
    biggest = payload["attention"][-1]
    payload["us_per_call"] = biggest["us_fused"]
    payload["derived"] = biggest["fused_speedup_vs_two_kernel"]
    save_result("decode_hotpath", payload)
    path = save_result("BENCH_decode", payload)
    print(f"wrote {path}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny slot counts / few iters (CI bench-smoke)")
    ap.add_argument("--full", action="store_true",
                    help="add the 256-slot cell")
    ap.add_argument("--validate", action="store_true",
                    help="only validate the committed snapshot's schema")
    args = ap.parse_args()
    if args.validate:
        payload = load_result("BENCH_decode")
        if payload is None:
            raise SystemExit("no BENCH_decode.json snapshot to validate")
        errs = validate(payload)
        if errs:
            raise SystemExit("invalid snapshot: " + "; ".join(errs))
        print("BENCH_decode.json schema OK "
              f"({len(payload['attention'])} attention rows, "
              f"{len(payload['lm_decode'])} lm rows)")
        return
    if args.smoke:
        payload = {
            "schema_version": SCHEMA_VERSION,
            "methodology": {
                "backend": jax.default_backend(),
                "kernel_mode": "interpret",
                "timing": "smoke run (CI)",
            },
            "attention": run_attention_level((2, 8), iters=5),
            "lm_decode": run_lm_level((2,), iters=3),
        }
        errs = validate(payload, require_win=False)
        if errs:
            raise SystemExit("smoke schema invalid: " + "; ".join(errs))
        print("bench smoke OK")
        return
    run(fast=not args.full)


if __name__ == "__main__":
    sys.exit(main())
