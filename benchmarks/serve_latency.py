"""Serving latency vs context length: PRF O(1)-state decode wall-clock is
flat in context, exact-attention KV decode grows. (The at-scale version is
the decode_32k == long_500k equality in the §Roofline table; this is the
measured-on-CPU reduced-model counterpart.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.models import lm
from benchmarks.common import save_result, time_call


def run(fast: bool = True) -> dict:
    cfg_lin = cfgs.get_config("smollm-135m", reduced=True)
    cfg_ex = cfgs.darkify(cfg_lin, "exact")
    params = lm.init_params(jax.random.PRNGKey(0), cfg_lin)
    params_e = lm.init_params(jax.random.PRNGKey(0), cfg_ex)
    tok = jnp.zeros((2,), jnp.int32)
    rows = []
    for ctx in (256, 1024, 4096) if fast else (256, 1024, 4096, 16384):
        st_l = lm.init_serve_state(cfg_lin, b=2, max_len=ctx)
        st_e = lm.init_serve_state(cfg_ex, b=2, max_len=ctx)
        dec_l = jax.jit(lambda p, t, s: lm.decode_step(p, cfg_lin, t, s))
        dec_e = jax.jit(lambda p, t, s: lm.decode_step(p, cfg_ex, t, s))
        # warm the states to mid-context so exact attends over ctx/2 keys
        st_e["pos"] = jnp.asarray(ctx // 2, jnp.int32)
        us_l = time_call(lambda: dec_l(params, tok, st_l)[0], iters=8)
        us_e = time_call(lambda: dec_e(params_e, tok, st_e)[0], iters=8)
        rows.append({"ctx": ctx, "us_linear": us_l, "us_exact": us_e})
        print(f"  serve ctx={ctx}: linear={us_l:.0f}us exact={us_e:.0f}us",
              flush=True)
    flat = rows[-1]["us_linear"] / max(rows[0]["us_linear"], 1e-9)
    grow = rows[-1]["us_exact"] / max(rows[0]["us_exact"], 1e-9)
    out = {"rows": rows, "linear_growth": flat, "exact_growth": grow,
           "us_per_call": rows[-1]["us_linear"],
           "derived": grow / max(flat, 1e-9)}
    save_result("serve_latency", out)
    return out


if __name__ == "__main__":
    r = run()
    print("linear growth:", round(r["linear_growth"], 2),
          " exact growth:", round(r["exact_growth"], 2))
