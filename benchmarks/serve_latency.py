"""Serving benchmarks: decode-cost scaling + continuous-batching traffic.

Part 1 (context scaling): PRF O(1)-state decode wall-clock is flat in
context, exact-attention KV decode grows. (The at-scale version is the
decode_32k == long_500k equality in the §Roofline table; this is the
measured-on-CPU reduced-model counterpart.)

Part 2 (engine throughput): open-loop Poisson traffic through
``repro.serving.ServingEngine`` — requests with heterogeneous prompt and
generation lengths arrive at a fixed rate and get multiplexed over a
small slot pool. Reports tokens/s, p50/p99 per-token latency (TPOT),
p50/p99 TTFT and mean slot occupancy, for the PRF kernel vs the exact
paged-KV fallback.

Part 3 (chunked prefill): mixed traffic — short decode-heavy requests
sharing the pool with a long-prompt admission — under blocking
(``chunk_tokens=None``) vs chunked admission. The long prefill stalls
every active decode slot in blocking mode; chunking bounds the stall by
the chunk execution time. Reports the short requests' TPOT p50/p99/max
("stall") and the long request's TTFT for both schedules (tracked
snapshot: experiments/bench/BENCH_serve_chunked.json).

Part 4 (batched prefill): an admission burst — several prompts arriving
together — under the serial one-admission-per-step schedule
(``prefill_rows=1``) vs the packed multi-admission schedule (all staged
rows advance in ONE padded prefill-chunk call per step), at matched
per-row chunk size. Per-call cost is sublinear in rows, so packing
compresses the admission pipeline ~n_burst x for a much smaller
increase in per-step stall. Reports burst wall time, last-admission
TTFT and the prefill call/batch stats for both (tracked snapshot:
experiments/bench/BENCH_serve_batched.json).

Part 6 (prefix cache): prefix-heavy traffic — >= 80% of requests share
one long prompt prefix (the system-prompt / few-shot template shape) —
through the engine with and without ``prefix_cache``. With the cache,
the first request's chunked prefill captures block-aligned snapshots
and every later sharer is admitted by FORKING the snapshot (one
broadcast scatter; cursor starts at the cached length), so only its
private suffix is prefilled; exact configs run the same traffic on the
paged-KV layout where forks share prefix pages copy-on-write. Reports
TTFT p50/p99, prefill tokens actually computed, and the cache's
hit/fork/eviction counters for both modes and both kinds. Acceptance
bar: cache-on TTFT p50 at least 2x better than cache-off at this reuse
level for the PRF kind (tracked snapshot:
experiments/bench/BENCH_serve_prefix.json, schema-validated on write
and by the CI bench-smoke job).

Part 5 (overlapped serving): the sequential vs pipelined step loop
(``ServingEngine(overlap=...)``) under a Poisson admission storm at
MATCHED traffic — same request trace, same slots/chunk budget. The
sequential loop pays a host sync per decode step AND per admission
(first-token fetches), and its decode readback queues behind the step's
prefill chunk; the overlapped loop dispatches decode first, defers the
chunk's merge, and retires tokens from a one-step-delayed buffer, so
the only per-step block is on a decode that has had a full step of
device time to finish. Reports steady-state decode TPOT p50/p99, TTFT,
and the pipeline counters (``decode_stall_ms`` — host blocked on token
readiness — and ``dispatch_depth``), plus the measured latency of one
packed prefill chunk: the acceptance bar is overlap TPOT p99 <=
sequential TPOT p99 with the overlap decode stall bounded below that
chunk latency (tracked snapshot:
experiments/bench/BENCH_serve_overlap.json, schema-validated on write
and by the CI bench-smoke job).
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.models import lm
from repro.serving import PrefixCacheConfig, Request, ServingEngine
from repro.serving.request import synthetic_requests
from benchmarks.common import load_result, save_result, time_call

SCHEMA_VERSION = 1
PREFIX_SCHEMA_VERSION = 1

# every per-scheduler row of the overlap benchmark must carry these
REQUIRED_MODE_KEYS = ("tok_per_s", "tpot_p50_ms", "tpot_p99_ms",
                      "ttft_p50_ms", "ttft_p99_ms",
                      "decode_stall_ms_p50", "decode_stall_ms_p99",
                      "decode_stall_ms_max", "dispatch_depth_mean")

# every cache_off/cache_on row of the prefix-cache benchmark
PREFIX_MODE_KEYS = ("tok_per_s", "ttft_p50_ms", "ttft_p99_ms",
                    "prefill_tokens")


def run_context_scaling(fast: bool = True) -> dict:
    cfg_lin = cfgs.get_config("smollm-135m", reduced=True)
    cfg_ex = cfgs.darkify(cfg_lin, "exact")
    params = lm.init_params(jax.random.PRNGKey(0), cfg_lin)
    params_e = lm.init_params(jax.random.PRNGKey(0), cfg_ex)
    tok = jnp.zeros((2,), jnp.int32)
    rows = []
    for ctx in (256, 1024, 4096) if fast else (256, 1024, 4096, 16384):
        st_l = lm.init_serve_state(cfg_lin, b=2, max_len=ctx)
        st_e = lm.init_serve_state(cfg_ex, b=2, max_len=ctx)
        dec_l = jax.jit(lambda p, t, s: lm.decode_step(p, cfg_lin, t, s))
        dec_e = jax.jit(lambda p, t, s: lm.decode_step(p, cfg_ex, t, s))
        # warm the states to mid-context so exact attends over ctx/2 keys
        st_e["pos"] = jnp.asarray(ctx // 2, jnp.int32)
        us_l = time_call(lambda: dec_l(params, tok, st_l)[0], iters=8)
        us_e = time_call(lambda: dec_e(params_e, tok, st_e)[0], iters=8)
        rows.append({"ctx": ctx, "us_linear": us_l, "us_exact": us_e})
        print(f"  serve ctx={ctx}: linear={us_l:.0f}us exact={us_e:.0f}us",
              flush=True)
    flat = rows[-1]["us_linear"] / max(rows[0]["us_linear"], 1e-9)
    grow = rows[-1]["us_exact"] / max(rows[0]["us_exact"], 1e-9)
    return {"rows": rows, "linear_growth": flat, "exact_growth": grow,
            "us_per_call": rows[-1]["us_linear"],
            "derived": grow / max(flat, 1e-9)}


def run_engine_traffic(fast: bool = True, rate: float = 4.0,
                       slots: int = 4) -> dict:
    """Poisson open-loop traffic through the continuous-batching engine.

    The ``darkformer+fused`` row is the same traffic with
    ``use_kernel=True`` — decode through the fused megakernel with the
    engine-precomposed projections — giving the engine-level
    before/after of the ISSUE-4 decode restructure."""
    n_req = 8 if fast else 32
    out = {}
    for label in ("darkformer", "darkformer+fused", "exact"):
        kind, _, variant = label.partition("+")
        cfg = cfgs.get_config("smollm-135m", reduced=True)
        cfg = cfgs.darkify(cfg, kind, cfg.attn.num_features)
        if variant == "fused":
            import dataclasses
            cfg = dataclasses.replace(cfg, use_kernel=True)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServingEngine(params, cfg, max_slots=slots, max_len=96,
                            chunk_tokens=8)
        for r in synthetic_requests(n_req, cfg.vocab, seed=1, rate=rate,
                                    prompt_range=(8, 48),
                                    gen_range=(8, 24)):
            eng.submit(r)
        results = eng.run(realtime=False)
        st = eng.stats
        tpots = np.array([t for r in results for t in r.tpots])
        ttfts = np.array([r.ttft for r in results if r.token_times])
        span = (max(r.finish_time for r in results)
                - min(r.arrival_time for r in results))
        row = {
            "requests": n_req, "rate": rate, "slots": slots,
            "tokens": st["emitted_tokens"],
            "tok_per_s": st["emitted_tokens"] / max(span, 1e-9),
            "tpot_p50_ms": float(np.percentile(tpots, 50) * 1e3)
            if tpots.size else None,
            "tpot_p99_ms": float(np.percentile(tpots, 99) * 1e3)
            if tpots.size else None,
            "ttft_p50_ms": float(np.percentile(ttfts, 50) * 1e3),
            "ttft_p99_ms": float(np.percentile(ttfts, 99) * 1e3),
            "mean_occupancy": st["mean_occupancy"],
            "decode_steps": st["decode_steps"],
        }
        out[label] = row
        print(f"  engine[{label}]: {row['tok_per_s']:.1f} tok/s, "
              f"tpot p50={row['tpot_p50_ms']:.1f}ms "
              f"p99={row['tpot_p99_ms']:.1f}ms, "
              f"occupancy={row['mean_occupancy'] * 100:.0f}%", flush=True)
    return out


def _rand_prompt(rng, vocab, l):
    return [rng.randrange(vocab) for _ in range(l)]


def _mixed_traffic_pass(eng, vocab, *, seed, long_len, short_gen):
    """Drive the canonical mixed trace: 3 short decode-heavy requests
    fill slots, then a long prompt admits mid-decode. Returns
    (short_results, long_result)."""
    import random
    rng = random.Random(seed)
    short_uids = [eng.submit(Request(
        prompt=_rand_prompt(rng, vocab, 8 + 2 * i),
        max_new_tokens=short_gen)) for i in range(3)]
    for _ in range(3):
        eng.step()                      # shorts admitted + decoding
    long_uid = eng.submit(Request(prompt=_rand_prompt(rng, vocab,
                                                      long_len),
                                  max_new_tokens=4))
    results = {r.uid: r for r in eng.run()}
    return [results[u] for u in short_uids], results[long_uid]


def run_chunked_prefill(fast: bool = True, chunk_tokens: int = 128,
                        long_len: int = 1024) -> dict:
    """Blocking vs chunked admission under mixed long-prompt + decode
    traffic. The metric that matters is the short requests' worst-case
    TPOT ("stall"): blocking admission executes the whole long prompt
    between two decode steps; chunking caps it at chunk_tokens. Each
    schedule is measured over several repeats of the trace (after a
    compile-warmup pass on the same engine) so the p99 reflects the
    repeated stall events, not one-off host noise."""
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    short_gen = 24 if fast else 48
    reps = 3 if fast else 6
    out = {"chunk_tokens": chunk_tokens, "long_len": long_len}
    for label, chunk in (("blocking", None), ("chunked", chunk_tokens)):
        eng = ServingEngine(params, cfg, max_slots=4, max_len=2048,
                            chunk_tokens=chunk)
        # warmup pass compiles every chunk/prompt length in the trace
        _mixed_traffic_pass(eng, cfg.vocab, seed=1, long_len=long_len,
                            short_gen=short_gen)
        tpots, ttfts = [], []
        for rep in range(reps):
            shorts, long_res = _mixed_traffic_pass(
                eng, cfg.vocab, seed=2 + rep, long_len=long_len,
                short_gen=short_gen)
            tpots += [t for r in shorts for t in r.tpots]
            ttfts.append(long_res.ttft)
        tpots = np.array(tpots)
        st = eng.stats
        row = {
            "tpot_p50_ms": float(np.percentile(tpots, 50) * 1e3),
            "tpot_p99_ms": float(np.percentile(tpots, 99) * 1e3),
            "tpot_max_ms": float(tpots.max() * 1e3),
            "long_ttft_ms": float(np.median(ttfts) * 1e3),
            "max_prefill_tokens_per_step":
                st["max_prefill_tokens_per_step"],
        }
        out[label] = row
        print(f"  admission[{label}]: short tpot "
              f"p50={row['tpot_p50_ms']:.1f}ms "
              f"p99={row['tpot_p99_ms']:.1f}ms "
              f"max={row['tpot_max_ms']:.1f}ms, "
              f"long ttft={row['long_ttft_ms']:.0f}ms, "
              f"max prefill/step={row['max_prefill_tokens_per_step']}",
              flush=True)
    out["stall_improvement"] = (out["blocking"]["tpot_p99_ms"]
                                / max(out["chunked"]["tpot_p99_ms"], 1e-9))
    save_result("BENCH_serve_chunked", out)
    return out


def run_batched_prefill(fast: bool = True, row_chunk: int = 32,
                        n_burst: int = 4, prompt_len: int = 96) -> dict:
    """Serial vs batched multi-admission prefill under an admission
    burst (n_burst equal prompts at once), at MATCHED per-row chunk
    size: the serial schedule (prefill_rows=1, chunk_tokens=row_chunk)
    advances one admission by row_chunk tokens per step, so the burst
    admits in n_burst x (prompt/row_chunk) steps; the packed schedule
    (chunk_tokens=n_burst*row_chunk) advances EVERY staged row by
    row_chunk in one padded (P, L) call, admitting the whole burst in
    prompt/row_chunk steps. Per-call cost is sublinear in rows, so the
    packed schedule trades a < n_burst x per-step stall for an
    n_burst x shorter admission pipeline. Reports wall time to drain
    the burst, last-admission TTFT (the metric the packed schedule
    compresses), per-step prefill stall, and the packer's
    call/occupancy stats."""
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    gen = 8 if fast else 16
    reps = 3 if fast else 6
    out = {"row_chunk": row_chunk, "n_burst": n_burst,
           "prompt_len": prompt_len}
    import random
    import time as _t
    schedules = (("serial", 1, row_chunk),
                 ("batched", None, n_burst * row_chunk))
    for label, rows, chunk in schedules:
        eng = ServingEngine(params, cfg, max_slots=n_burst, max_len=256,
                            chunk_tokens=chunk, prefill_rows=rows)
        rng = random.Random(0)

        def burst_pass(eng, rng):
            now = eng._now()                 # engine-clock arrivals so
            uids = [eng.submit(Request(      # TTFT is per-pass, not
                prompt=_rand_prompt(rng, cfg.vocab, prompt_len),
                max_new_tokens=gen, arrival_time=now))
                for _ in range(n_burst)]     # cumulative
            start = _t.perf_counter()
            res = {r.uid: r for r in eng.run()}
            wall = _t.perf_counter() - start
            return [res[u] for u in uids], wall

        burst_pass(eng, rng)                 # compile warmup
        ttfts, walls = [], []
        for _ in range(reps):
            results, wall = burst_pass(eng, rng)
            ttfts.append(max(r.ttft for r in results))   # last admission
            walls.append(wall)
        st = eng.stats
        row = {
            "chunk_tokens": chunk,
            "burst_wall_ms": float(np.median(walls) * 1e3),
            "last_ttft_ms": float(np.median(ttfts) * 1e3),
            "prefill_stall_per_step": st["max_prefill_tokens_per_step"],
            "prefill_calls": st["prefill_calls"],
            "prefill_rows_per_call": st["prefill_rows_per_call"],
            "prefill_batch_occupancy": st["prefill_batch_occupancy"],
        }
        out[label] = row
        print(f"  prefill[{label}]: burst wall={row['burst_wall_ms']:.0f}ms "
              f"last ttft={row['last_ttft_ms']:.0f}ms, "
              f"{row['prefill_calls']} calls "
              f"({row['prefill_rows_per_call']:.1f} rows/call, "
              f"occupancy {row['prefill_batch_occupancy'] * 100:.0f}%, "
              f"stall<={row['prefill_stall_per_step']} tok/step)",
              flush=True)
    out["last_ttft_improvement"] = (out["serial"]["last_ttft_ms"]
                                    / max(out["batched"]["last_ttft_ms"],
                                          1e-9))
    save_result("BENCH_serve_batched", out)
    return out


def _measure_chunk_latency_ms(cfg, params, p_rows: int,
                              chunk: int) -> float:
    """Median wall time of ONE packed (P, chunk) prefill-chunk call on
    the engine's hot path (precomposed projections, layer-stacked
    params) — the denominator of the "decode stall bounded below one
    prefill-chunk latency" acceptance bar."""
    stacked = lm.can_stack_layers(cfg)
    st = lm.init_serve_state(cfg, b=p_rows, max_len=2 * chunk,
                             per_slot=True, stacked=stacked)
    proj = lm.build_decode_proj(params, cfg, stacked=stacked)
    sp = params
    if stacked:
        sp = dict(params)
        sp["layers"] = lm.stack_layer_params(params, cfg)
    toks = jnp.zeros((p_rows, chunk), jnp.int32)
    fn = jax.jit(lambda pa, pr, s, t: lm.prefill_chunk(
        pa, cfg, {"tokens": t}, s, proj=pr)[0])
    return time_call(lambda: fn(sp, proj, st, toks), iters=8) / 1e3


def _storm_pass(eng, vocab, *, seed, n_req, rate):
    """One Poisson admission storm against a warm engine: arrivals are
    offset to the engine's current clock so each pass reproduces the
    same relative trace."""
    now = eng._now()
    reqs = synthetic_requests(n_req, vocab, seed=seed, rate=rate,
                              prompt_range=(8, 48), gen_range=(8, 24))
    for r in reqs:
        r.arrival_time += now
        eng.submit(r)
    return eng.run(realtime=False)


def run_overlapped_serving(fast: bool = True, slots: int = 4,
                           chunk_tokens: int = 16,
                           rate: float = 16.0) -> dict:
    """Sequential vs overlapped step loop at matched Poisson traffic
    (module docstring, part 5). Writes + validates the tracked
    BENCH_serve_overlap.json snapshot."""
    n_req = 16 if fast else 48
    reps = 3 if fast else 6
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    out = {
        "schema_version": SCHEMA_VERSION,
        "methodology": {
            "backend": jax.default_backend(),
            "timing": "token-readiness clocks (engine blocks on the "
                      "device buffer before stamping token_times); "
                      f"{reps} storm repeats on a warm engine, compile "
                      "warmup pass excluded from every percentile",
            "traffic": f"{n_req} requests/storm, Poisson rate={rate}/s, "
                       f"prompts 8-48, gen 8-24, {slots} slots, "
                       f"chunk_tokens={chunk_tokens}, darkformer",
            "note": "CPU numbers — the tracked claim is the relative "
                    "sequential-vs-overlap ordering and the stall bound, "
                    "not absolute ms",
        },
        "chunk_latency_ms": _measure_chunk_latency_ms(
            cfg, params, p_rows=slots, chunk=chunk_tokens),
    }
    for label, overlap in (("sequential", False), ("overlap", True)):
        eng = ServingEngine(params, cfg, max_slots=slots, max_len=96,
                            chunk_tokens=chunk_tokens, seed=0,
                            overlap=overlap)
        # warmup pass compiles every shape in the trace; drop its
        # stall/depth samples so percentiles reflect steady state
        _storm_pass(eng, cfg.vocab, seed=7, n_req=n_req, rate=rate)
        eng._stall_ms.clear()
        eng._depths.clear()
        tpots, ttfts, results = [], [], []
        for rep in range(reps):
            res = _storm_pass(eng, cfg.vocab, seed=11 + rep,
                              n_req=n_req, rate=rate)
            results += res
            tpots += [t for r in res for t in r.tpots]
            ttfts += [r.ttft for r in res if r.token_times]
        st = eng.stats
        tpots = np.array(tpots)
        spans = [max(r.finish_time for r in results)
                 - min(r.arrival_time for r in results)]
        row = {
            "tok_per_s": sum(len(r.tokens) for r in results)
            / max(spans[0], 1e-9),
            "tpot_p50_ms": float(np.percentile(tpots, 50) * 1e3),
            "tpot_p99_ms": float(np.percentile(tpots, 99) * 1e3),
            "ttft_p50_ms": float(np.percentile(ttfts, 50) * 1e3),
            "ttft_p99_ms": float(np.percentile(ttfts, 99) * 1e3),
            "decode_stall_ms_p50": st["decode_stall_ms_p50"],
            "decode_stall_ms_p99": st["decode_stall_ms_p99"],
            "decode_stall_ms_max": st["decode_stall_ms_max"],
            "dispatch_depth_mean": st["dispatch_depth_mean"],
            "dispatch_depth_max": st["dispatch_depth_max"],
        }
        out[label] = row
        print(f"  scheduler[{label}]: tpot p50={row['tpot_p50_ms']:.1f}ms "
              f"p99={row['tpot_p99_ms']:.1f}ms, "
              f"ttft p99={row['ttft_p99_ms']:.0f}ms, "
              f"stall p99={row['decode_stall_ms_p99']:.2f}ms "
              f"(chunk={out['chunk_latency_ms']:.2f}ms), "
              f"depth mean={row['dispatch_depth_mean']:.1f}", flush=True)
    out["tpot_p99_improvement"] = (out["sequential"]["tpot_p99_ms"]
                                   / max(out["overlap"]["tpot_p99_ms"],
                                         1e-9))
    out["stall_bounded"] = bool(out["overlap"]["decode_stall_ms_p99"]
                                < out["chunk_latency_ms"])
    errs = validate(out)
    if errs:
        raise SystemExit("BENCH_serve_overlap invalid: " + "; ".join(errs))
    path = save_result("BENCH_serve_overlap", out)
    print(f"wrote {path}")
    return out


def _prefix_pass(eng, vocab, prefix, *, seed, n_req, rate, reuse,
                 suffix_range=(16, 31), gen_range=(8, 16)):
    """One prefix-heavy storm against a warm engine: a ``reuse``
    fraction of requests open with the FIXED ``prefix`` (so snapshots
    captured on earlier passes keep hitting), the rest are random
    control prompts of the same length; arrivals are Poisson at
    ``rate`` offset to the engine clock."""
    import random
    rng = random.Random(seed)
    now, t, reqs = eng._now(), 0.0, []
    for _ in range(n_req):
        if rate > 0:
            t += rng.expovariate(rate)
        suffix = [rng.randrange(vocab)
                  for _ in range(rng.randint(*suffix_range))]
        if rng.random() < reuse:
            prompt = list(prefix) + suffix
        else:
            prompt = [rng.randrange(vocab)
                      for _ in range(len(prefix))] + suffix
        reqs.append(Request(prompt=prompt,
                            max_new_tokens=rng.randint(*gen_range),
                            arrival_time=now + t))
    for r in reqs:
        eng.submit(r)
    return eng.run(realtime=False)


def run_prefix_cache(fast: bool = True, slots: int = 4,
                     chunk_tokens: int = 32, prefix_len: int = 128,
                     reuse: float = 0.85, rate: float = 16.0,
                     smoke: bool = False) -> dict:
    """Prefix-heavy traffic with vs without the prefix cache (module
    docstring, part 6), for the PRF kind (snapshot fork) and the exact
    kind (paged KV, copy-on-write fork). Writes + validates the
    tracked BENCH_serve_prefix.json snapshot (skipped under
    ``smoke``, which only checks the schema on a tiny run)."""
    if smoke:
        n_req, reps, prefix_len, chunk_tokens, slots = 4, 1, 32, 16, 2
        max_len, block = 96, 16
    else:
        n_req = 12 if fast else 32
        reps = 2 if fast else 4
        max_len, block = 192, 32
    pc = PrefixCacheConfig(block_tokens=block, page_size=16)
    out = {
        "schema_version": PREFIX_SCHEMA_VERSION,
        "methodology": {
            "backend": jax.default_backend(),
            "timing": "token-readiness clocks; warmup storm (compile + "
                      "prefix capture) excluded from every percentile, "
                      f"{reps} measured storms on the warm engine",
            "traffic": f"{n_req} requests/storm, Poisson rate={rate}/s, "
                       f"shared prefix={prefix_len} tokens at "
                       f"reuse={reuse:.0%}, suffixes 16-31, gen 8-16, "
                       f"{slots} slots, chunk_tokens={chunk_tokens}, "
                       f"block_tokens={block}",
            "note": "CPU numbers — the tracked claim is the cache-on "
                    "vs cache-off TTFT ordering at this reuse level, "
                    "not absolute ms",
        },
        "reuse": reuse,
        "prefix_len": prefix_len,
        "kinds": {},
    }
    import random
    for kind in ("darkformer", "exact"):
        cfg = cfgs.get_config("smollm-135m", reduced=True)
        cfg = cfgs.darkify(cfg, kind, cfg.attn.num_features)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        # the shared prefix is FIXED (not derived from the storm seed)
        # so the warmup pass's captured snapshots serve every later pass
        prng = random.Random(42)
        prefix = [prng.randrange(cfg.vocab) for _ in range(prefix_len)]
        krow = {}
        for mode, cache in (("cache_off", None), ("cache_on", pc)):
            eng = ServingEngine(params, cfg, max_slots=slots,
                                max_len=max_len, seed=0,
                                chunk_tokens=chunk_tokens,
                                prefix_cache=cache)
            # warmup compiles the trace's packed-prefill shapes (two
            # full-size storms — one is not enough combination
            # coverage) and, cache-on, captures the shared prefix's
            # block-aligned snapshots
            for wseed in (5, 6):
                _prefix_pass(eng, cfg.vocab, prefix, seed=wseed,
                             rate=rate, n_req=n_req, reuse=reuse)
            base_prefill = eng.stats["prefill_tokens"]
            ttfts, results = [], []
            for rep in range(reps):
                res = _prefix_pass(eng, cfg.vocab, prefix,
                                   seed=21 + rep, n_req=n_req,
                                   rate=rate, reuse=reuse)
                results += res
                ttfts += [r.ttft for r in res if r.token_times]
            st = eng.stats
            span = (max(r.finish_time for r in results)
                    - min(r.arrival_time for r in results))
            row = {
                "tok_per_s": sum(len(r.tokens) for r in results)
                / max(span, 1e-9),
                "ttft_p50_ms": float(np.percentile(ttfts, 50) * 1e3),
                "ttft_p99_ms": float(np.percentile(ttfts, 99) * 1e3),
                # prefill actually computed in the measured storms —
                # forked admissions skip the cached prefix entirely
                "prefill_tokens": st["prefill_tokens"] - base_prefill,
            }
            if cache is not None:
                row.update({
                    "prefix_hit_rate": st["prefix_hit_rate"],
                    "forked_requests": st["forked_requests"],
                    "forked_tokens": st["forked_tokens"],
                    "prefix_captures": st["prefix_captures"],
                    "prefix_evictions": st["prefix_evictions"],
                    "snapshot_device_bytes": st["prefix_device_bytes"],
                    "paged_kv": bool(st.get("paged_kv", False)),
                })
                if st.get("paged_kv"):
                    row["kv_pages_free"] = st["kv_pages_free"]
                    row["kv_pages_total"] = st["kv_pages_total"]
            krow[mode] = row
            extra = (f", hits={st['prefix_hits']} "
                     f"forked={st['forked_tokens']} tok"
                     if cache is not None else "")
            print(f"  prefix[{kind}/{mode}]: "
                  f"ttft p50={row['ttft_p50_ms']:.0f}ms "
                  f"p99={row['ttft_p99_ms']:.0f}ms, "
                  f"prefill={row['prefill_tokens']} tok{extra}",
                  flush=True)
        krow["ttft_p50_improvement"] = (
            krow["cache_off"]["ttft_p50_ms"]
            / max(krow["cache_on"]["ttft_p50_ms"], 1e-9))
        krow["prefill_token_reduction"] = (
            krow["cache_off"]["prefill_tokens"]
            / max(krow["cache_on"]["prefill_tokens"], 1))
        out["kinds"][kind] = krow
        print(f"  prefix[{kind}]: ttft p50 improvement "
              f"{krow['ttft_p50_improvement']:.2f}x, prefill tokens "
              f"{krow['prefill_token_reduction']:.2f}x fewer", flush=True)
    errs = validate_prefix(out, require_win=not smoke)
    if errs:
        raise SystemExit("BENCH_serve_prefix invalid: " + "; ".join(errs))
    if not smoke:
        path = save_result("BENCH_serve_prefix", out)
        print(f"wrote {path}")
    return out


def validate_prefix(payload: dict, require_win: bool = True) -> list[str]:
    """Schema check for the BENCH_serve_prefix snapshot. Returns a
    list of problems (empty == valid). ``require_win`` also enforces
    the ISSUE-10 acceptance bar — cache-on TTFT p50 at least 2x better
    than cache-off at >= 80% prefix reuse for the PRF kind — on for
    tracked snapshots, off for CI smoke machines where only the
    schema is the contract."""
    errs = []
    if payload.get("schema_version") != PREFIX_SCHEMA_VERSION:
        errs.append(f"schema_version != {PREFIX_SCHEMA_VERSION}")
    meth = payload.get("methodology", {})
    for key in ("backend", "timing", "traffic"):
        if not isinstance(meth.get(key), str):
            errs.append(f"methodology.{key} missing")
    kinds = payload.get("kinds", {})
    for kind in ("darkformer", "exact"):
        krow = kinds.get(kind)
        if not isinstance(krow, dict):
            errs.append(f"kinds.{kind}: missing")
            continue
        for mode in ("cache_off", "cache_on"):
            row = krow.get(mode)
            if not isinstance(row, dict):
                errs.append(f"{kind}.{mode}: missing")
                continue
            for key in PREFIX_MODE_KEYS:
                if not isinstance(row.get(key), (int, float)):
                    errs.append(f"{kind}.{mode}: lacks numeric {key!r}")
        on = krow.get("cache_on", {})
        if isinstance(on, dict):
            for key in ("prefix_hit_rate", "forked_tokens",
                        "prefix_captures"):
                if not isinstance(on.get(key), (int, float)):
                    errs.append(f"{kind}.cache_on: lacks numeric {key!r}")
        if not isinstance(krow.get("ttft_p50_improvement"), (int, float)):
            errs.append(f"kinds.{kind}: lacks ttft_p50_improvement")
    exact_on = kinds.get("exact", {}).get("cache_on", {})
    if isinstance(exact_on, dict) and exact_on and \
            not exact_on.get("paged_kv"):
        errs.append("exact.cache_on must run the paged-KV layout "
                    "(paged_kv: true)")
    if require_win and not errs:
        imp = payload["kinds"]["darkformer"]["ttft_p50_improvement"]
        if imp < 2.0:
            errs.append(
                "prefix cache must improve TTFT p50 by >= 2x at this "
                "reuse level for the PRF kind (acceptance bar of "
                f"ISSUE 10); got {imp:.2f}x")
    return errs


def validate(payload: dict, require_win: bool = True) -> list[str]:
    """Schema check for the BENCH_serve_overlap snapshot. Returns a
    list of problems (empty == valid). ``require_win`` also enforces
    the ISSUE-8 acceptance bar — overlap decode p99 TPOT no worse than
    sequential at matched traffic, with the overlap decode stall
    bounded below one prefill-chunk latency — on for tracked
    snapshots, off for noisy CI smoke machines where only the schema
    is the contract."""
    errs = []
    if payload.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version != {SCHEMA_VERSION}")
    meth = payload.get("methodology", {})
    for key in ("backend", "timing", "traffic"):
        if not isinstance(meth.get(key), str):
            errs.append(f"methodology.{key} missing")
    if not isinstance(payload.get("chunk_latency_ms"), (int, float)):
        errs.append("chunk_latency_ms missing")
    for mode in ("sequential", "overlap"):
        row = payload.get(mode)
        if not isinstance(row, dict):
            errs.append(f"{mode}: missing")
            continue
        for key in REQUIRED_MODE_KEYS:
            if not isinstance(row.get(key), (int, float)):
                errs.append(f"{mode}: lacks numeric {key!r}")
    if require_win and not errs:
        if payload["tpot_p99_improvement"] < 1.0:
            errs.append(
                "overlap decode p99 TPOT must be no worse than the "
                "sequential loop at matched traffic (acceptance bar of "
                f"ISSUE 8); got {payload['tpot_p99_improvement']:.2f}x")
        if not payload.get("stall_bounded"):
            errs.append(
                "overlap decode stall p99 "
                f"({payload['overlap']['decode_stall_ms_p99']:.2f}ms) "
                "must stay below one prefill-chunk latency "
                f"({payload['chunk_latency_ms']:.2f}ms)")
    return errs


def run(fast: bool = True) -> dict:
    scaling = run_context_scaling(fast)
    traffic = run_engine_traffic(fast)
    chunked = run_chunked_prefill(fast)
    batched = run_batched_prefill(fast)
    overlap = run_overlapped_serving(fast)
    prefix = run_prefix_cache(fast)
    out = {**scaling, "traffic": traffic, "chunked_prefill": chunked,
           "batched_prefill": batched, "overlapped_serving": overlap,
           "prefix_cache": prefix}
    save_result("serve_latency", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny overlap- and prefix-section runs + "
                         "schema checks (CI bench-smoke; no snapshot "
                         "written)")
    ap.add_argument("--full", action="store_true",
                    help="more requests/repeats per section")
    ap.add_argument("--validate", action="store_true",
                    help="only validate the committed "
                         "BENCH_serve_overlap and BENCH_serve_prefix "
                         "snapshots' schemas")
    args = ap.parse_args()
    if args.validate:
        payload = load_result("BENCH_serve_overlap")
        if payload is None:
            raise SystemExit("no BENCH_serve_overlap.json snapshot "
                             "to validate")
        errs = validate(payload)
        if errs:
            raise SystemExit("invalid snapshot: " + "; ".join(errs))
        print("BENCH_serve_overlap.json schema OK (tpot p99 "
              f"{payload['tpot_p99_improvement']:.2f}x, stall p99 "
              f"{payload['overlap']['decode_stall_ms_p99']:.2f}ms < "
              f"chunk {payload['chunk_latency_ms']:.2f}ms)")
        payload = load_result("BENCH_serve_prefix")
        if payload is None:
            raise SystemExit("no BENCH_serve_prefix.json snapshot "
                             "to validate")
        errs = validate_prefix(payload)
        if errs:
            raise SystemExit("invalid snapshot: " + "; ".join(errs))
        dk = payload["kinds"]["darkformer"]
        print("BENCH_serve_prefix.json schema OK (ttft p50 "
              f"{dk['ttft_p50_improvement']:.2f}x, hit rate "
              f"{dk['cache_on']['prefix_hit_rate']:.0%}, exact paged "
              f"{payload['kinds']['exact']['ttft_p50_improvement']:.2f}x)")
        return
    if args.smoke:
        cfg = cfgs.get_config("smollm-135m", reduced=True)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        payload = {
            "schema_version": SCHEMA_VERSION,
            "methodology": {"backend": jax.default_backend(),
                            "timing": "smoke run (CI)",
                            "traffic": "smoke: 4 requests, 2 slots"},
            "chunk_latency_ms": _measure_chunk_latency_ms(
                cfg, params, p_rows=2, chunk=8),
        }
        for label, overlap in (("sequential", False), ("overlap", True)):
            eng = ServingEngine(params, cfg, max_slots=2, max_len=64,
                                chunk_tokens=8, seed=0, overlap=overlap)
            res = _storm_pass(eng, cfg.vocab, seed=3, n_req=4, rate=32.0)
            st = eng.stats
            tpots = np.array([t for r in res for t in r.tpots])
            ttfts = [r.ttft for r in res if r.token_times]
            payload[label] = {
                "tok_per_s": sum(len(r.tokens) for r in res),
                "tpot_p50_ms": float(np.percentile(tpots, 50) * 1e3),
                "tpot_p99_ms": float(np.percentile(tpots, 99) * 1e3),
                "ttft_p50_ms": float(np.percentile(ttfts, 50) * 1e3),
                "ttft_p99_ms": float(np.percentile(ttfts, 99) * 1e3),
                **{k: st[k] for k in ("decode_stall_ms_p50",
                                      "decode_stall_ms_p99",
                                      "decode_stall_ms_max",
                                      "dispatch_depth_mean",
                                      "dispatch_depth_max")},
            }
        payload["tpot_p99_improvement"] = (
            payload["sequential"]["tpot_p99_ms"]
            / max(payload["overlap"]["tpot_p99_ms"], 1e-9))
        payload["stall_bounded"] = bool(
            payload["overlap"]["decode_stall_ms_p99"]
            < payload["chunk_latency_ms"])
        errs = validate(payload, require_win=False)
        if errs:
            raise SystemExit("smoke schema invalid: " + "; ".join(errs))
        run_prefix_cache(smoke=True)      # validates its own schema
        print("serve_latency bench smoke OK")
        return
    r = run(fast=not args.full)
    print("linear growth:", round(r["linear_growth"], 2),
          " exact growth:", round(r["exact_growth"], 2))
    for kind, row in r["traffic"].items():
        print(f"{kind}: {row['tok_per_s']:.1f} tok/s "
              f"@ occupancy {row['mean_occupancy'] * 100:.0f}%")
    print("chunked admission p99-stall improvement: "
          f"{r['chunked_prefill']['stall_improvement']:.1f}x")
    print("overlap tpot-p99 improvement: "
          f"{r['overlapped_serving']['tpot_p99_improvement']:.2f}x")
    for kind, krow in r["prefix_cache"]["kinds"].items():
        print(f"prefix-cache ttft-p50 improvement [{kind}]: "
              f"{krow['ttft_p50_improvement']:.2f}x")


if __name__ == "__main__":
    sys.exit(main())
