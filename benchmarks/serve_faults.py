"""Serving fault-tolerance benchmark: kernel ladder, health probe,
straggler-detect + quarantine recovery.

Part 1 (ladder): the SAME greedy batch drained through the serving
engine at every rung of the decode implementation ladder —
``fused_kernel`` (the prf_fused_* megakernels against the
engine-precomposed projections, ``cfg.use_kernel``), ``two_stage_kernel``
(the legacy jnp-featmap + carry-scan-kernel oracle, reachable only via
the lm-level ``fused=False`` entry points, which the rung pins for the
engine's jitted steps), and ``jnp`` (pure-XLA reference). The tracked
claim is ``streams_match``: all three rungs emit bitwise-identical
greedy token streams, so a fleet can fall DOWN the ladder (kernel
regression, new backend) without changing served outputs.

Part 2 (health probe): the drain repeated with a per-step
``StragglerMonitor`` (repro/runtime/fault_tolerance.py) latency EMA plus
a periodic all-finite sweep over the live slot pool — the serving
analogue of the trainer's health loop. The tracked claim is that the
probe is ~free (``health_overhead`` ~1x wall), so there is no excuse to
serve blind.

Part 3 (recovery): a straggler fault injected mid-decode (one engine
step artificially stalled); the monitor flags it in ``detect_steps``
steps, the victim request is quarantined (``ServingEngine.cancel`` —
its in-flight work is dropped), and the drain completes. The tracked
claim is ``survivors_bitwise_identical``: the surviving slots' token
streams equal the fault-free reference run — per-slot state isolation
means one bad sequence never perturbs its neighbours.

Tracked snapshot: experiments/bench/BENCH_serve_faults.json
(schema-validated on write and by the CI bench-smoke job).
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import functools
import sys
import time
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.models import lm
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.serving import Request, ServingEngine
from benchmarks.common import load_result, save_result

SCHEMA_VERSION = 1

LADDER_RUNGS = ("fused_kernel", "two_stage_kernel", "jnp")
LADDER_KEYS = ("tok_per_s", "tpot_p50_ms", "tpot_p99_ms", "wall_ms")


def _prompts(vocab, n_req):
    import random
    rng = random.Random(0)
    return [[rng.randrange(vocab) for _ in range(rng.randint(12, 24))]
            for _ in range(n_req)]


def _drain(eng, prompts, gen):
    """Submit the batch, drain, return (per-request results in submit
    order, wall seconds)."""
    uids = [eng.submit(Request(prompt=p, max_new_tokens=gen))
            for p in prompts]
    t0 = time.perf_counter()
    res = {r.uid: r for r in eng.run()}
    wall = time.perf_counter() - t0
    return [res[u] for u in uids], wall


def _two_stage_ctx():
    """Pin the lm-level serve entry points to ``fused=False`` (the
    two-stage oracle) for the lifetime of a rung: the engine's jitted
    steps trace through ``lm.decode_step`` / ``lm.prefill_chunk`` on
    first call, and the engine itself only ever selects the fused or
    pure-jnp paths (engine._resolve_serve_paths)."""
    dec, pre = lm.decode_step, lm.prefill_chunk
    return mock.patch.multiple(
        lm,
        decode_step=functools.partial(dec, fused=False),
        prefill_chunk=functools.partial(pre, fused=False))


def _make_engine(params, cfg, rung, slots, chunk_tokens):
    kcfg = dataclasses.replace(cfg, use_kernel=(rung != "jnp"))
    return ServingEngine(params, kcfg, max_slots=slots, max_len=96,
                         chunk_tokens=chunk_tokens, seed=0)


def run_ladder(params, cfg, *, n_req, gen, slots, chunk_tokens) -> dict:
    """Drain the same greedy batch at every rung; bitwise-compare the
    emitted streams."""
    prompts = _prompts(cfg.vocab, n_req)
    out, streams = {}, {}
    for rung in LADDER_RUNGS:
        ctx = _two_stage_ctx() if rung == "two_stage_kernel" else \
            contextlib.nullcontext()
        with ctx:
            eng = _make_engine(params, cfg, rung, slots, chunk_tokens)
            _drain(eng, prompts, gen)          # compile warmup
            results, wall = _drain(eng, prompts, gen)
        streams[rung] = [tuple(r.tokens) for r in results]
        tpots = np.array([t for r in results for t in r.tpots])
        n_tok = sum(len(r.tokens) for r in results)
        out[rung] = {
            "tok_per_s": n_tok / max(wall, 1e-9),
            "tpot_p50_ms": float(np.percentile(tpots, 50) * 1e3),
            "tpot_p99_ms": float(np.percentile(tpots, 99) * 1e3),
            "wall_ms": wall * 1e3,
        }
        print(f"  ladder[{rung}]: {out[rung]['tok_per_s']:.0f} tok/s, "
              f"tpot p50={out[rung]['tpot_p50_ms']:.2f}ms, "
              f"wall={out[rung]['wall_ms']:.0f}ms", flush=True)
    out["streams_match"] = bool(
        all(streams[r] == streams[LADDER_RUNGS[0]] for r in LADDER_RUNGS))
    print(f"  ladder streams_match={out['streams_match']}", flush=True)
    return out


@functools.partial(jax.jit, static_argnums=())
def _tree_finite(tree):
    """ONE fused all-finite reduction over the floating leaves (a
    per-leaf host sync would dominate the probe's cost)."""
    flags = [jnp.isfinite(leaf).all()
             for leaf in jax.tree_util.tree_leaves(tree)
             if jnp.issubdtype(leaf.dtype, jnp.floating)]
    return jnp.stack(flags).all()


def _pool_finite(eng) -> bool:
    return bool(_tree_finite(eng.pool))


def run_health_probe(eng, prompts, gen, probe_every: int = 4) -> dict:
    """Wall time of the drain with vs without the per-step monitor +
    periodic pool-finiteness sweep. Same warm engine both passes."""
    _, off = _drain(eng, prompts, gen)
    _pool_finite(eng)                          # compile the probe
    mon = StragglerMonitor(threshold=3.0, warmup_steps=4)
    uids = [eng.submit(Request(prompt=p, max_new_tokens=gen))
            for p in prompts]
    t0 = time.perf_counter()
    i = 0
    while eng.has_work:
        s0 = time.perf_counter()
        eng.step()
        mon.record(i, time.perf_counter() - s0)
        if probe_every and i % probe_every == probe_every - 1:
            if not _pool_finite(eng):
                raise SystemExit("health probe: non-finite slot state")
        i += 1
    on = time.perf_counter() - t0
    del uids
    row = {"health_on": on * 1e3, "health_off": off * 1e3,
           "health_overhead": on / max(off, 1e-9)}
    print(f"  health probe: {row['health_overhead']:.2f}x wall overhead "
          f"({mon.straggler_steps} stragglers flagged in steady state)",
          flush=True)
    return row


def run_recovery(eng, prompts, gen, stall_at: int = 6) -> dict:
    """Inject one stalled engine step mid-decode; the StragglerMonitor
    detects it, the victim request is quarantined via ``cancel`` (its
    in-flight work dropped), and the survivors must finish with token
    streams bitwise-equal to a fault-free reference drain."""
    refs, _ = _drain(eng, prompts, gen)        # fault-free reference
    mon = StragglerMonitor(threshold=3.0, warmup_steps=4)
    uids = [eng.submit(Request(prompt=p, max_new_tokens=gen))
            for p in prompts]
    victim, detect_steps, quarantined = uids[0], 0, 0
    i, finished = 0, []
    while eng.has_work:
        s0 = time.perf_counter()
        finished.extend(eng.step())
        dt = time.perf_counter() - s0
        if i == stall_at:                      # the fault: one stalled
            time.sleep(0.05)                   # step (dead host, link
            dt = time.perf_counter() - s0      # flap) lands in the EMA
        flagged = mon.record(i, dt)
        if i >= stall_at and not quarantined:
            detect_steps += 1
            if flagged:                        # detector fired: evict
                eng.cancel(victim)             # the straggling sequence
                quarantined = 1
        i += 1
    res = {r.uid: r for r in finished}
    survivors = [(j, u) for j, u in enumerate(uids) if u != victim]
    survivors_ok = all(
        u in res and tuple(res[u].tokens) == tuple(refs[j].tokens)
        for j, u in survivors)
    row = {"detect_steps": detect_steps, "quarantined": quarantined,
           "failed": 1,
           "survivors_bitwise_identical": bool(survivors_ok)}
    print(f"  recovery: detected in {detect_steps} step(s), "
          f"survivors bitwise identical={row['survivors_bitwise_identical']}",
          flush=True)
    return row


def validate(payload: dict, require_win: bool = True) -> list[str]:
    """Schema check for the BENCH_serve_faults snapshot. Returns a list
    of problems (empty == valid). ``require_win`` also enforces the
    correctness bars — cross-rung stream equality and bitwise-identical
    survivors — on for tracked snapshots, off for CI smoke machines
    where only the schema is the contract (the bars themselves are not
    timing-noise-sensitive, but smoke runs may shrink the traffic below
    what makes them meaningful)."""
    errs = []
    if payload.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version != {SCHEMA_VERSION}")
    meth = payload.get("methodology", {})
    for key in ("backend", "timing", "traffic"):
        if not isinstance(meth.get(key), str):
            errs.append(f"methodology.{key} missing")
    ladder = payload.get("ladder")
    if not isinstance(ladder, dict):
        errs.append("ladder: missing")
    else:
        for rung in LADDER_RUNGS:
            row = ladder.get(rung)
            if not isinstance(row, dict):
                errs.append(f"ladder.{rung}: missing")
                continue
            for key in LADDER_KEYS:
                if not isinstance(row.get(key), (int, float)):
                    errs.append(f"ladder.{rung}: lacks numeric {key!r}")
        if not isinstance(ladder.get("streams_match"), bool):
            errs.append("ladder.streams_match missing")
    hp = payload.get("health_probe")
    if not isinstance(hp, dict):
        errs.append("health_probe: missing")
    else:
        for key in ("health_on", "health_off", "health_overhead"):
            if not isinstance(hp.get(key), (int, float)):
                errs.append(f"health_probe: lacks numeric {key!r}")
    rec = payload.get("recovery")
    if not isinstance(rec, dict):
        errs.append("recovery: missing")
    else:
        for key in ("detect_steps", "quarantined", "failed"):
            if not isinstance(rec.get(key), int):
                errs.append(f"recovery: lacks integer {key!r}")
        if not isinstance(rec.get("survivors_bitwise_identical"), bool):
            errs.append("recovery.survivors_bitwise_identical missing")
    if require_win and not errs:
        if not ladder["streams_match"]:
            errs.append("kernel-ladder greedy streams must be bitwise "
                        "identical across rungs")
        if not rec["survivors_bitwise_identical"]:
            errs.append("survivors of a quarantined sequence must match "
                        "the fault-free reference bitwise")
        if not rec["quarantined"]:
            errs.append("the injected straggler was never quarantined")
    return errs


def run(fast: bool = True, slots: int = 3, chunk_tokens: int = 16,
        smoke: bool = False) -> dict:
    if smoke:
        n_req, gen = 3, 6
    else:
        n_req, gen = (6, 16) if fast else (12, 32)
    cfg = cfgs.get_config("smollm-135m", reduced=True)
    cfg = cfgs.darkify(cfg, "darkformer", cfg.attn.num_features)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    out = {
        "schema_version": SCHEMA_VERSION,
        "methodology": {
            "backend": jax.default_backend(),
            "timing": "wall time of full drain (submit -> flush) on a "
                      "warm engine; compile warmup pass excluded",
            "traffic": f"greedy batch of {n_req} (prompts 12-24, "
                       f"gen {gen}), darkformer reduced smollm-135m, "
                       f"{slots} slots, chunk_tokens={chunk_tokens}",
            "note": "CPU numbers — the tracked claims are the "
                    "cross-rung stream equality, the ~1x health-probe "
                    "overhead and the recovery guarantees, not "
                    "absolute ms",
        },
        "ladder": run_ladder(params, cfg, n_req=n_req, gen=gen,
                             slots=slots, chunk_tokens=chunk_tokens),
    }
    prompts = _prompts(cfg.vocab, n_req)
    eng = _make_engine(params, cfg, "jnp", slots, chunk_tokens)
    _drain(eng, prompts, gen)                  # compile warmup
    out["health_probe"] = run_health_probe(eng, prompts, gen)
    out["recovery"] = run_recovery(eng, prompts, gen)
    out["us_per_call"] = out["ladder"]["fused_kernel"]["tpot_p50_ms"] * 1e3
    out["derived"] = out["health_probe"]["health_overhead"]
    errs = validate(out, require_win=not smoke)
    if errs:
        raise SystemExit("BENCH_serve_faults invalid: " + "; ".join(errs))
    if not smoke:
        path = save_result("BENCH_serve_faults", out)
        print(f"wrote {path}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run + schema check (CI bench-smoke; no "
                         "snapshot written)")
    ap.add_argument("--full", action="store_true",
                    help="more requests / longer generations")
    ap.add_argument("--validate", action="store_true",
                    help="only validate the committed BENCH_serve_faults "
                         "snapshot's schema + correctness bars")
    args = ap.parse_args()
    if args.validate:
        payload = load_result("BENCH_serve_faults")
        if payload is None:
            raise SystemExit("no BENCH_serve_faults.json snapshot "
                             "to validate")
        errs = validate(payload)
        if errs:
            raise SystemExit("invalid snapshot: " + "; ".join(errs))
        print("BENCH_serve_faults.json schema OK (streams_match="
              f"{payload['ladder']['streams_match']}, health overhead "
              f"{payload['health_probe']['health_overhead']:.2f}x, "
              "survivors bitwise="
              f"{payload['recovery']['survivors_bitwise_identical']})")
        return
    if args.smoke:
        run(smoke=True)
        print("serve_faults bench smoke OK")
        return
    r = run(fast=not args.full)
    print("health overhead: "
          f"{r['health_probe']['health_overhead']:.2f}x, streams_match: "
          f"{r['ladder']['streams_match']}")


if __name__ == "__main__":
    sys.exit(main())
