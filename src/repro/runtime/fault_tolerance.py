"""Fault tolerance for 1000+-node training runs.

Pieces (all exercised by tests / the launcher on this single-host container,
designed for the multi-host deployment):

  * TrainSupervisor — wraps the step loop: periodic checkpoints, automatic
    restore-on-restart, retry-from-checkpoint on step failure (the software
    analogue of a node dying mid-step), bounded restart budget.
  * SimulatedFailure — deterministic fault injector for tests/drills
    (raise at step N; the supervisor must recover and converge to the same
    final state as an uninterrupted run — see tests/test_fault_tolerance).
  * PreemptionHandler — SIGTERM/SIGINT -> "checkpoint now and exit 0"
    (maps to TPU maintenance-event preemption notices).
  * StragglerMonitor — per-step latency EMA; steps slower than
    ``threshold x EMA`` are counted and reported. On a real fleet the
    report feeds the scheduler's hot-swap of the slow host; here it
    triggers a log line + callback hook.
  * elastic_shrink_plan — given a failed-host count, compute the largest
    (data, model)-consistent submesh and the checkpoint resharding plan;
    paired with checkpoint.restore_to_shardings this is the
    shrink-and-continue path.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax

from repro import checkpoint as ckpt_lib


class SimulatedFailure(RuntimeError):
    """Injected fault (stands in for a dead host / ICI link flap)."""


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0          # x EMA
    ema_decay: float = 0.9
    warmup_steps: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _ema: float = 0.0
    _n: int = 0
    straggler_steps: int = 0

    def record(self, step: int, dt: float) -> bool:
        """Record a step latency; returns True if flagged as straggler."""
        self._n += 1
        if self._n <= self.warmup_steps:
            self._ema = dt if self._ema == 0.0 else (
                self.ema_decay * self._ema + (1 - self.ema_decay) * dt)
            return False
        flagged = dt > self.threshold * self._ema
        if flagged:
            self.straggler_steps += 1
            if self.on_straggler:
                self.on_straggler(step, dt, self._ema)
        else:
            # only fold non-outlier steps into the EMA
            self._ema = (self.ema_decay * self._ema
                         + (1 - self.ema_decay) * dt)
        return flagged


class PreemptionHandler:
    """SIGTERM/SIGINT -> graceful 'checkpoint and stop' flag."""

    def __init__(self, install: bool = True):
        self.preempted = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
                signal.signal(signal.SIGINT, self._handler)
            except ValueError:
                pass                      # non-main thread (tests)

    def _handler(self, signum, frame):
        self.preempted = True


def elastic_shrink_plan(mesh_shape: tuple[int, ...], axis_names: tuple,
                        failed_hosts: int, devices_per_host: int = 4
                        ) -> tuple[int, ...]:
    """Largest valid submesh after losing ``failed_hosts`` hosts.

    Policy: shrink the DATA axis (model sharding is fixed by memory), in
    whole-host multiples, to the largest power-of-two divisor that fits.
    Returns the new mesh shape; restore via checkpoint.restore_to_shardings.
    """
    shape = dict(zip(axis_names, mesh_shape))
    lost_devices = failed_hosts * devices_per_host
    total = 1
    for s in mesh_shape:
        total *= s
    remaining = total - lost_devices
    model = shape.get("model", 1)
    pod = shape.get("pod", 1)
    per_replica = model
    max_data = remaining // (per_replica * pod)
    if max_data < 1:
        raise ValueError("cluster too small after failures")
    data = 1
    while data * 2 <= max_data:
        data *= 2
    new = dict(shape)
    new["data"] = data
    return tuple(new[a] for a in axis_names)


@dataclasses.dataclass
class TrainSupervisor:
    """Checkpointed, restartable, straggler-aware step-loop driver."""
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    monitor: StragglerMonitor = dataclasses.field(
        default_factory=StragglerMonitor)
    preemption: Optional[PreemptionHandler] = None

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            num_steps: int,
            fail_at: Optional[int] = None,
            on_metrics: Optional[Callable[[int, Any], None]] = None) -> Any:
        """Run ``num_steps`` of ``step_fn`` with checkpoint/restart.

        ``state`` must be a pytree including everything needed to resume
        (params, optimizer state, step counter is managed here).
        ``fail_at`` injects a SimulatedFailure once at that step.
        """
        start = 0
        restored = self._try_restore(state)
        if restored is not None:
            state, start = restored
            start += 1
        restarts = 0
        injected = False
        step = start
        while step < num_steps:
            t0 = time.monotonic()
            try:
                if fail_at is not None and step == fail_at and not injected:
                    injected = True
                    raise SimulatedFailure(f"injected failure @ step {step}")
                state = step_fn(state, step)
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                restored = self._try_restore(state)
                if restored is None:
                    step = 0            # no checkpoint yet: restart cold
                else:
                    state, last = restored
                    step = last + 1
                continue
            self.monitor.record(step, time.monotonic() - t0)
            if on_metrics:
                on_metrics(step, state)
            preempt = self.preemption is not None and \
                self.preemption.preempted
            if (step % self.ckpt_every == self.ckpt_every - 1) or \
                    step == num_steps - 1 or preempt:
                ckpt_lib.save_checkpoint(self.ckpt_dir, step, state,
                                         keep=self.keep)
            if preempt:
                break
            step += 1
        return state

    def _try_restore(self, template: Any):
        last = ckpt_lib.latest_step(self.ckpt_dir)
        if last is None:
            return None
        tree, step = ckpt_lib.restore_checkpoint(self.ckpt_dir, template,
                                                 last)
        return tree, step
