"""Runtime: fault tolerance, preemption, stragglers, elastic scaling."""
from repro.runtime.fault_tolerance import (TrainSupervisor, SimulatedFailure,
                                           StragglerMonitor,
                                           PreemptionHandler,
                                           elastic_shrink_plan)
