"""Closed-form Monte-Carlo-variance math for PRF estimators.

Implements the paper's theory layer so it can be validated numerically:

  * ``optimal_sigma_star``      — Theorem 3.2: Sigma* = (I+2L)(I-2L)^{-1}
  * ``b_gaussian``              — B_x(w) for x ~ N(0, L) in closed form
                                  (Appendix A: prod_i c_i exp(beta_i w'_i^2))
  * ``estimator_variance_iso``  — Var_w[kappa_hat] for w ~ N(0, I) (exact)
  * ``estimator_variance_is``   — Var for the importance-sampled estimator
                                  with Gaussian proposal N(0, S) (Lemma 3.1's
                                  objective, exact Gaussian integrals)
  * ``estimator_variance_dark`` — Var of DARKFormer's *unweighted* estimator
                                  of its data-aligned kernel exp(q^T S k)
  * ``expected_variance``       — E_{q,k~D}[Var] by closed-form inner
                                  expectation + MC over (q, k)

All terms are per-sample variances; the m-sample estimator divides by m.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def optimal_sigma_star(lam: Array) -> Array:
    """Theorem 3.2: Sigma* = (I + 2*Lam)(I - 2*Lam)^{-1}.

    Valid when lambda_max(Lam) < 1/2 (integrability of psi*). Computed in
    the eigenbasis for symmetry/stability.
    """
    evals, evecs = jnp.linalg.eigh(lam)
    star = (1.0 + 2.0 * evals) / (1.0 - 2.0 * evals)
    return (evecs * star[None, :]) @ evecs.T


def b_gaussian(omega: Array, lam: Array) -> Array:
    """B_x(omega) = E_{x~N(0,Lam)}[exp(2 w.x - ||x||^2)], exact.

    = |I + 2 Lam|^{-1/2} * exp( 2 w^T Lam (I + 2 Lam)^{-1} w ).
    omega: (..., d).
    """
    d = lam.shape[-1]
    eye = jnp.eye(d, dtype=lam.dtype)
    a = eye + 2.0 * lam
    sign, logdet = jnp.linalg.slogdet(a)
    inner = jnp.einsum("...d,de,...e->...", omega,
                       lam @ jnp.linalg.inv(a), omega)
    return jnp.exp(2.0 * inner - 0.5 * logdet)


def kappa_softmax(q: Array, k: Array) -> Array:
    return jnp.exp(jnp.sum(q * k, axis=-1))


def estimator_variance_iso(q: Array, k: Array) -> Array:
    """Exact per-sample Var_w[Z], w ~ N(0, I), Z the PRF summand (Lemma 2.1).

    E[Z^2] = exp(2||q+k||^2 - ||q||^2 - ||k||^2);  Var = E[Z^2] - exp(2 q.k).
    """
    s = q + k
    ez2 = jnp.exp(2.0 * jnp.sum(s * s, axis=-1)
                  - jnp.sum(q * q, axis=-1) - jnp.sum(k * k, axis=-1))
    return ez2 - kappa_softmax(q, k) ** 2


def estimator_variance_is(q: Array, k: Array, sigma_psi: Array) -> Array:
    """Exact per-sample Var of the IS estimator (Eq. 2) with psi = N(0, S).

    Z = (p_I/psi)(w) exp(w.s - a),  a = (||q||^2+||k||^2)/2,  w ~ psi.
    E[Z^2] = 2^{-d/2} |S|^{1/2} |A|^{-1/2} exp(s^T A^{-1} s - 2a),
    with A = I - S^{-1}/2, requires A > 0 (finite variance).
    """
    d = sigma_psi.shape[-1]
    eye = jnp.eye(d, dtype=sigma_psi.dtype)
    s_inv = jnp.linalg.inv(sigma_psi)
    a_mat = eye - 0.5 * s_inv
    s = q + k
    _, logdet_s = jnp.linalg.slogdet(sigma_psi)
    _, logdet_a = jnp.linalg.slogdet(a_mat)
    quad = jnp.einsum("...d,de,...e->...", s, jnp.linalg.inv(a_mat), s)
    two_a = jnp.sum(q * q, axis=-1) + jnp.sum(k * k, axis=-1)
    log_ez2 = (-0.5 * d * jnp.log(2.0) + 0.5 * logdet_s - 0.5 * logdet_a
               + quad - two_a)
    return jnp.exp(log_ez2) - kappa_softmax(q, k) ** 2


def estimator_variance_dark(q: Array, k: Array, sigma: Array) -> Array:
    """Var of DARKFormer's unweighted estimator of exp(q^T Sigma k) (Eq. 3).

    Z = exp(w.s - (q^T S q + k^T S k)/2), w ~ N(0, S).
    E[Z^2] = exp(2 s^T S s - q^T S q - k^T S k).
    """
    s = q + k
    def quad(x):
        return jnp.einsum("...d,de,...e->...", x, sigma, x)
    ez2 = jnp.exp(2.0 * quad(s) - quad(q) - quad(k))
    ez = jnp.exp(jnp.einsum("...d,de,...e->...", q, sigma, k))
    return ez2 - ez ** 2


def expected_variance(keys: Array, lam: Array, sigma_psi: Array | None,
                      n_pairs: int = 4096) -> Array:
    """E_{q,k~N(0,Lam)}[Var_w[kappa_hat]] — closed-form inner, MC outer.

    sigma_psi None -> isotropic baseline; else the IS proposal N(0, S).
    """
    d = lam.shape[-1]
    chol = jnp.linalg.cholesky(lam)
    kq, kk = jax.random.split(keys)
    q = jax.random.normal(kq, (n_pairs, d)) @ chol.T
    k = jax.random.normal(kk, (n_pairs, d)) @ chol.T
    if sigma_psi is None:
        v = estimator_variance_iso(q, k)
    else:
        v = estimator_variance_is(q, k, sigma_psi)
    return jnp.mean(v)


def importance_weight(omega: Array, sigma: Array) -> Array:
    """w_Sigma(omega) = p_Sigma(omega) / p_I(omega)  (Proposition 4.1)."""
    d = sigma.shape[-1]
    _, logdet = jnp.linalg.slogdet(sigma)
    s_inv = jnp.linalg.inv(sigma)
    quad_s = jnp.einsum("...d,de,...e->...", omega, s_inv, omega)
    quad_i = jnp.sum(omega * omega, axis=-1)
    return jnp.exp(-0.5 * logdet - 0.5 * quad_s + 0.5 * quad_i)


def mc_kernel_estimate(q: Array, k: Array, omegas: Array,
                       weights: Array | None = None) -> Array:
    """m-sample PRF estimate of exp(q.k) (optionally importance-weighted).

    q, k: (..., d); omegas: (m, d); weights: (m,) or None.
    """
    zq = jnp.exp(jnp.einsum("md,...d->...m", omegas, q)
                 - 0.5 * jnp.sum(q * q, axis=-1, keepdims=True))
    zk = jnp.exp(jnp.einsum("md,...d->...m", omegas, k)
                 - 0.5 * jnp.sum(k * k, axis=-1, keepdims=True))
    z = zq * zk
    if weights is not None:
        z = z * weights
    return jnp.mean(z, axis=-1)


def mc_dark_estimate(q: Array, k: Array, omegas: Array, sigma: Array) -> Array:
    """m-sample unweighted DARKFormer estimate of exp(q^T Sigma k).

    omegas must be drawn from N(0, Sigma).
    """
    def quad(x):
        return jnp.einsum("...d,de,...e->...", x, sigma, x)
    zq = jnp.exp(jnp.einsum("md,...d->...m", omegas, q)
                 - 0.5 * quad(q)[..., None])
    zk = jnp.exp(jnp.einsum("md,...d->...m", omegas, k)
                 - 0.5 * quad(k)[..., None])
    return jnp.mean(zq * zk, axis=-1)


def empirical_qk_covariance(q: Array, k: Array) -> Array:
    """Pooled covariance of flattened q/k vectors — calibration input.

    q, k: (..., d). Used to whiten (M = Lam^{-1/2}) or to form Sigma*.
    """
    x = jnp.concatenate([q.reshape(-1, q.shape[-1]),
                         k.reshape(-1, k.shape[-1])], axis=0)
    x = x - jnp.mean(x, axis=0, keepdims=True)
    return (x.T @ x) / x.shape[0]
