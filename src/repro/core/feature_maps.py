"""Positive random feature (PRF) maps — the paper's core objects.

Implements, as pure functions over explicit parameter pytrees:

  * ``performer``  — isotropic PRFs, Choromanski et al. 2021 (Eq. 1):
        phi(x) = exp(W x - ||x||^2 / 2 - c) / sqrt(m),  W ~ N(0, I)  (rows)
  * ``darkformer`` — data-aware PRFs with learned covariance Sigma = M^T M
    (paper Eq. 3). Realized through the identity  phi_Sigma(x) = phi_iso(M x):
        x~ = M x;  phi(x) = exp(W x~ - ||x~||^2 / 2 - c) / sqrt(m)
    which draws omega~ = M^T w,  w ~ N(0, I_r), i.e. omega~ ~ N(0, Sigma) and
    is unbiased for exp(q^T Sigma k).
  * ``lfk``        — learned feature kernel baseline: W itself is trainable.
  * ``trig``       — trigonometric random features (background §2), for
    reference/benchmarks only.

All maps share the numerical stabilizer ``c``: PRFs are exp() of possibly
large logits; we subtract a data-dependent max (stop-gradiented) exactly like
the Performer reference implementation. The stabilizer cancels in the
attention normalization (it multiplies numerator and denominator equally) so
the attention output is exact in infinite precision.

Shapes (single head):
  x : (..., L, d)       queries or keys (scaling by d^{-1/4} pre-applied
                        by the caller so that q'k' = qk/sqrt(d))
  W : (m, r)            projection matrix (feature space)
  M : (r, d)            DARKFormer re-embedding (Sigma = M^T M), r <= d
  out: (..., L, m)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

FEATURE_KINDS = ("exact", "performer", "darkformer", "lfk", "trig",
                 "random", "constant")

# kinds with a decode-time PRF (S, z, c) state — and hence a fused
# decode path (precompose_projection / prf_fused_decode)
PRF_KINDS = ("performer", "darkformer", "lfk")


@dataclasses.dataclass(frozen=True)
class FeatureConfig:
    """Configuration of the random-feature attention kernel."""
    kind: str = "darkformer"         # one of FEATURE_KINDS
    num_features: int = 256          # m
    feature_rank: int = 0            # r for DARKFormer; 0 -> r = d_head
    orthogonal: bool = True          # blockwise-orthogonal W (Performer trick)
    stabilize: bool = True           # subtract running max before exp
    eps: float = 1e-8                # denominator floor (f32 accumulators;
                                     # keep small — the stabilizer shrinks
                                     # denominators by exp(-c))
    redraw: bool = False             # redraw W each step (training) or fix

    def rank(self, d_head: int) -> int:
        return self.feature_rank if self.feature_rank > 0 else d_head


# ---------------------------------------------------------------------------
# Projection-matrix construction
# ---------------------------------------------------------------------------

def gaussian_projection(key: Array, m: int, r: int,
                        dtype=jnp.float32) -> Array:
    """Plain iid N(0,1) projection rows, shape (m, r)."""
    return jax.random.normal(key, (m, r), dtype=dtype)


def orthogonal_projection(key: Array, m: int, r: int,
                          dtype=jnp.float32) -> Array:
    """Blockwise-orthogonal Gaussian rows (Performer's ORF variance trick).

    Draws ceil(m/r) independent (r, r) Gaussian blocks, QR-orthogonalizes
    each, rescales rows to chi(r)-distributed norms so marginals match
    N(0, I_r) exactly, and stacks the first m rows.
    """
    nblocks = -(-m // r)
    keys = jax.random.split(key, nblocks + 1)
    blocks = []
    for i in range(nblocks):
        g = jax.random.normal(keys[i], (r, r), dtype=jnp.float32)
        q, _ = jnp.linalg.qr(g)
        blocks.append(q)
    w = jnp.concatenate(blocks, axis=0)[:m]
    # Row norms ~ chi(r): norms of iid gaussian vectors in R^r.
    norms = jnp.linalg.norm(
        jax.random.normal(keys[-1], (m, r), dtype=jnp.float32), axis=-1,
        keepdims=True)
    return (w * norms).astype(dtype)


def draw_projection(key: Array, cfg: FeatureConfig, d_head: int,
                    dtype=jnp.float32) -> Array:
    r = cfg.rank(d_head)
    if cfg.orthogonal:
        return orthogonal_projection(key, cfg.num_features, r, dtype)
    return gaussian_projection(key, cfg.num_features, r, dtype)


# ---------------------------------------------------------------------------
# Feature maps
# ---------------------------------------------------------------------------

def _stabilizer(logits: Array, stabilize: bool) -> Array:
    """max over (L, m) per leading batch dims; stop-grad; cancels in attn."""
    if not stabilize:
        return jnp.zeros(logits.shape[:-2] + (1, 1), logits.dtype)
    c = jnp.max(logits, axis=(-2, -1), keepdims=True)
    return jax.lax.stop_gradient(c)


def prf_features(x: Array, w: Array, *, stabilize: bool = True,
                 shared_stabilizer: Optional[Array] = None) -> Array:
    """Isotropic positive random features (Performer, paper Eq. 1).

    phi(x)_j = exp(w_j . x - ||x||^2/2 - c) / sqrt(m)
    ``shared_stabilizer`` lets q and k share one c (required so that the
    same constant multiplies numerator and denominator in attention).
    """
    m = w.shape[0]
    logits = jnp.einsum("...ld,md->...lm", x, w)
    sq = 0.5 * jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    raw = logits - sq
    c = (shared_stabilizer if shared_stabilizer is not None
         else _stabilizer(raw, stabilize))
    return jnp.exp(raw - c) / jnp.sqrt(m), c


def dark_features(x: Array, w: Array, m_mat: Array, *,
                  stabilize: bool = True,
                  shared_stabilizer: Optional[Array] = None) -> Array:
    """DARKFormer data-aware PRFs (paper Eq. 3): phi_Sigma(x) = phi_iso(Mx).

    x: (..., L, d), m_mat: (r, d), w: (m, r).
    Unbiased for exp(q^T Sigma k) with Sigma = M^T M.
    """
    x_tilde = jnp.einsum("...ld,rd->...lr", x, m_mat)
    return prf_features(x_tilde, w, stabilize=stabilize,
                        shared_stabilizer=shared_stabilizer)


def trig_features(x: Array, w: Array) -> Array:
    """Trigonometric random features for the softmax kernel (§2).

    h(x) = exp(+||x||^2/2); unbiased but can be negative -> unstable attn.
    Provided for benchmarks only.
    """
    m = w.shape[0]
    proj = jnp.einsum("...ld,md->...lm", x, w)
    h = jnp.exp(0.5 * jnp.sum(jnp.square(x), axis=-1, keepdims=True))
    feats = jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=-1)
    return h * feats / jnp.sqrt(m)


def qk_features(q: Array, k: Array, w: Array, kind: str,
                m_mat: Optional[Array] = None, *,
                stabilize: bool = True) -> tuple[Array, Array]:
    """Map (q, k) jointly with a shared stabilizer. Returns (q', k').

    q, k: (..., L, d) with the 1/sqrt(d) softmax scaling already absorbed
    (q = Q / d^{1/4}, k = K / d^{1/4}).
    """
    if kind == "performer" or kind == "lfk":
        # LFK differs only in W being a trained parameter, not a draw.
        qraw = jnp.einsum("...ld,md->...lm", q, w) - 0.5 * jnp.sum(
            jnp.square(q), axis=-1, keepdims=True)
        kraw = jnp.einsum("...ld,md->...lm", k, w) - 0.5 * jnp.sum(
            jnp.square(k), axis=-1, keepdims=True)
    elif kind == "darkformer":
        assert m_mat is not None, "darkformer needs the M matrix"
        qt = jnp.einsum("...ld,rd->...lr", q, m_mat)
        kt = jnp.einsum("...ld,rd->...lr", k, m_mat)
        qraw = jnp.einsum("...lr,mr->...lm", qt, w) - 0.5 * jnp.sum(
            jnp.square(qt), axis=-1, keepdims=True)
        kraw = jnp.einsum("...lr,mr->...lm", kt, w) - 0.5 * jnp.sum(
            jnp.square(kt), axis=-1, keepdims=True)
    else:
        raise ValueError(f"qk_features: unsupported kind {kind!r}")
    if stabilize:
        c = jax.lax.stop_gradient(
            jnp.maximum(jnp.max(qraw, axis=(-2, -1), keepdims=True),
                        jnp.max(kraw, axis=(-2, -1), keepdims=True)))
    else:
        c = jnp.zeros(qraw.shape[:-2] + (1, 1), qraw.dtype)
    m = w.shape[0]
    qf = jnp.exp(qraw - c) / jnp.sqrt(m)
    kf = jnp.exp(kraw - c) / jnp.sqrt(m)
    return qf, kf


def precompose_projection(fparams: dict, kind: str) -> dict:
    """Fold W and M into one decode-time projection A = (W M)^T.

    The fused decode megakernel (kernels/prf_fused_decode.py) computes
    raw logits as a SINGLE matmul ``x @ A`` instead of the chained
    ``(x M^T) W^T``; composing A once — at engine build, not per token
    — removes a serial matmul from the per-token hot path. ``m_mat``
    rides along for the darkformer norm term ‖Mx‖²/2 (None for the
    isotropic performer/lfk kinds, whose norm is ‖x‖²/2).

    ``fparams``: {"w": (..., m, r)[, "m_mat": (..., r, d)]} with any
    leading (layer-stack, group) axes. Returns {"a": (..., d, m),
    "m_mat": (..., r, d) | None} in f32.
    """
    if kind not in PRF_KINDS:
        raise ValueError(f"no decode projection for kind {kind!r}")
    w = fparams["w"].astype(jnp.float32)
    if kind == "darkformer":
        m_mat = fparams["m_mat"].astype(jnp.float32)
        a = jnp.einsum("...mr,...rd->...dm", w, m_mat)
        return {"a": a, "m_mat": m_mat}
    return {"a": jnp.swapaxes(w, -1, -2), "m_mat": None}


# ---------------------------------------------------------------------------
# Parameter initialization for the learned pieces
# ---------------------------------------------------------------------------

def init_feature_params(key: Array, cfg: FeatureConfig, d_head: int,
                        n_groups: int = 1, dtype=jnp.float32) -> dict:
    """Initialize per-layer feature-kernel params.

    Returns a dict pytree:
      w      : (n_groups, m, r)  — projection (buffer for performer/dark,
                                   trainable for lfk)
      m_mat  : (n_groups, r, d)  — DARKFormer re-embedding (trainable),
                                   identity-initialized (Sigma = I recovers
                                   the plain softmax kernel at init).
    n_groups lets GQA archs learn one Sigma per KV group.
    """
    r = cfg.rank(d_head)
    kw, km = jax.random.split(key)
    keys = jax.random.split(kw, n_groups)
    w = jnp.stack([draw_projection(k, cfg, d_head, dtype) for k in keys])
    params = {"w": w}
    if cfg.kind == "darkformer":
        eye = jnp.eye(r, d_head, dtype=dtype)
        params["m_mat"] = jnp.broadcast_to(
            eye, (n_groups, r, d_head)).copy()
    return params


def whitening_init(lam: Array, r: Optional[int] = None) -> Array:
    """M = Lambda^{-1/2} from a calibration covariance (App. C / Prop C.1).

    lam: (d, d) SPD covariance of q/k from a calibration batch. Returns
    (r, d) with the top-r whitening directions (full rank if r is None).
    """
    evals, evecs = jnp.linalg.eigh(lam)
    evals = jnp.maximum(evals, 1e-8)
    # eigh returns ascending order; take the largest-variance directions.
    inv_sqrt = evecs * jax.lax.rsqrt(evals)[None, :]
    m_full = inv_sqrt.T[::-1]          # rows sorted by descending variance
    if r is not None:
        m_full = m_full[:r]
    return m_full
