"""Calibration: estimate q/k covariance and initialize DARKFormer's M.

The paper's finetuning recipe: pretrained weights fix the q/k distribution;
a small calibration pass estimates per-layer (per KV group) covariance
Lambda and initializes M = Lambda^{-1/2} (whitening, App. C) or leaves
M = I (pure learned). ``calibrate_model`` runs a few batches through the
model's q/k projections and returns an updated param tree.
"""
from __future__ import annotations

from typing import Callable, Iterable

import jax
import jax.numpy as jnp

from repro.core import variance as vr
from repro.core import feature_maps as fm

Array = jax.Array


def shrinkage_covariance(x: Array, shrink: float = 0.05) -> Array:
    """Ledoit-Wolf-style diagonal shrinkage; keeps Lambda well-conditioned
    when the calibration sample is small."""
    d = x.shape[-1]
    x = x.reshape(-1, d)
    x = x - jnp.mean(x, axis=0, keepdims=True)
    cov = (x.T @ x) / x.shape[0]
    mu = jnp.trace(cov) / d
    return (1.0 - shrink) * cov + shrink * mu * jnp.eye(d, dtype=cov.dtype)


def whiten_m_from_qk(q: Array, k: Array, r: int | None = None,
                     shrink: float = 0.05) -> Array:
    """M = Lambda^{-1/2} (top-r rows) from sampled q/k activations."""
    d = q.shape[-1]
    lam = shrinkage_covariance(
        jnp.concatenate([q.reshape(-1, d), k.reshape(-1, d)], axis=0),
        shrink=shrink)
    return fm.whitening_init(lam, r)


def calibrate_feature_params(params: dict, qk_samples: dict,
                             cfg: fm.FeatureConfig) -> dict:
    """Replace each layer's identity-initialized m_mat by the whitening M.

    qk_samples: {layer_name: (q, k)} with q,k of shape (..., G, L, d) — one
    entry per attention layer, collected by the model's debug taps.
    Returns a new params pytree (functional update).
    """
    new = jax.tree_util.tree_map(lambda x: x, params)   # shallow copy tree
    for name, (q, k) in qk_samples.items():
        layer = new
        path = name.split("/")
        for p in path[:-1]:
            layer = layer[p]
        fp = layer[path[-1]]
        if "m_mat" not in fp:
            continue
        g = fp["m_mat"].shape[0]
        r = fp["m_mat"].shape[1]
        mats = []
        for gi in range(g):
            qg = q[..., gi, :, :]
            kg = k[..., gi, :, :]
            mats.append(whiten_m_from_qk(qg, kg, r))
        fp["m_mat"] = jnp.stack(mats).astype(fp["m_mat"].dtype)
    return new


def anisotropy_score(x: Array) -> Array:
    """Effective-rank-based anisotropy diagnostic: 1 - erank/d in [0, 1).

    0 for isotropic inputs; -> 1 as variance concentrates in one direction.
    Used by benchmarks to show the regimes where DARKFormer wins.
    """
    lam = shrinkage_covariance(x, shrink=0.0)
    evals = jnp.clip(jnp.linalg.eigvalsh(lam), 1e-12)
    p = evals / jnp.sum(evals)
    erank = jnp.exp(-jnp.sum(p * jnp.log(p)))
    return 1.0 - erank / x.shape[-1]
