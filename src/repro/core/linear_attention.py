"""Linear (random-feature) attention and exact-softmax references.

Layout convention everywhere: (B, H, L, D) — batch, heads, length, dim.
GQA is handled by the model layer (K/V carry n_kv heads; queries are
reshaped to (B, n_kv, group, L, D) before calling in here with H = n_kv and
the group folded into L-independent batch dims, or by repeating KV).

Three compute paths for the PRF numerator/denominator:

  * noncausal        — (Q' (K'^T V)) two-matmul form, O(L m d)
  * causal (chunked) — blockwise prefix state, O(L m d); pure-jnp version
                       here is the oracle for the Pallas kernel in
                       repro/kernels/linear_attn_scan.py
  * decode           — O(1) per-token state update (the serving path)

Exact softmax attention (causal / bidirectional / sliding-window) lives here
too, as the baseline the paper compares against.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Exact attention baselines
# ---------------------------------------------------------------------------

def exact_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: Optional[int] = None,
                    logit_dtype=jnp.float32) -> Array:
    """Softmax attention. q,k already scaled by d^{-1/4} each.

    window: sliding-window size (Mistral/Griffin-style local attention),
    counted inclusive of the current token.
    """
    l_q, l_k = q.shape[-2], k.shape[-2]
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(logit_dtype)
    idx_q = jnp.arange(l_q)[:, None] + (l_k - l_q)
    idx_k = jnp.arange(l_k)[None, :]
    mask = jnp.ones((l_q, l_k), dtype=bool)
    if causal:
        mask &= idx_k <= idx_q
    if window is not None:
        mask &= idx_k > idx_q - window
    logits = jnp.where(mask, logits, jnp.finfo(logit_dtype).min)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", probs, v).astype(v.dtype)


def constant_attention(v: Array, *, causal: bool = True) -> Array:
    """Uniform-weights baseline: causal running mean of V (paper §6)."""
    if causal:
        csum = jnp.cumsum(v.astype(jnp.float32), axis=-2)
        denom = jnp.arange(1, v.shape[-2] + 1, dtype=jnp.float32)
        return (csum / denom[:, None]).astype(v.dtype)
    return jnp.broadcast_to(jnp.mean(v, axis=-2, keepdims=True), v.shape)


def random_attention(key: Array, v: Array, *, causal: bool = True) -> Array:
    """Fixed random attention weights baseline (paper §6)."""
    l = v.shape[-2]
    logits = jax.random.normal(key, (l, l), dtype=jnp.float32)
    if causal:
        mask = jnp.tril(jnp.ones((l, l), dtype=bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("qk,...kd->...qd", probs, v).astype(v.dtype)


# ---------------------------------------------------------------------------
# Linear attention — noncausal (two matmuls)
# ---------------------------------------------------------------------------

def linear_attention_noncausal(qf: Array, kf: Array, v: Array,
                               eps: float = 1e-6) -> Array:
    """(Q' (K'^T V)) / (Q' sum_j K'_j). qf,kf: (..., L, m), v: (..., L, d)."""
    kv = jnp.einsum("...lm,...ld->...md", kf.astype(jnp.float32),
                    v.astype(jnp.float32))
    num = jnp.einsum("...lm,...md->...ld", qf.astype(jnp.float32), kv)
    ksum = jnp.sum(kf.astype(jnp.float32), axis=-2)
    den = jnp.einsum("...lm,...m->...l", qf.astype(jnp.float32), ksum)
    return (num / (den[..., None] + eps)).astype(v.dtype)


# ---------------------------------------------------------------------------
# Linear attention — causal
# ---------------------------------------------------------------------------

def linear_attention_causal_naive(qf: Array, kf: Array, v: Array,
                                  eps: float = 1e-6) -> Array:
    """O(L^2) masked reference — ground truth for tests only."""
    scores = jnp.einsum("...qm,...km->...qk", qf.astype(jnp.float32),
                        kf.astype(jnp.float32))
    l = qf.shape[-2]
    mask = jnp.tril(jnp.ones((l, l), dtype=bool))
    scores = jnp.where(mask, scores, 0.0)
    num = jnp.einsum("...qk,...kd->...qd", scores, v.astype(jnp.float32))
    den = jnp.sum(scores, axis=-1, keepdims=True)
    return (num / (den + eps)).astype(v.dtype)


def linear_attention_causal_carry(qf: Array, kf: Array, v: Array,
                                  s0: Optional[Array] = None,
                                  z0: Optional[Array] = None, *,
                                  chunk: int = 256, eps: float = 1e-6
                                  ) -> tuple[Array, Array, Array]:
    """Chunked prefix-state causal linear attention from a carried state.

    The pure-jnp oracle mirroring the Pallas kernel's blocking:
      per chunk c:   out_c = Q'_c S_in + tril(Q'_c K'_c^T) V_c
                     den_c = Q'_c z_in + tril(Q'_c K'_c^T) 1
                     S_out = S_in + K'_c^T V_c ;  z_out = z_in + sum K'_c
    ``s0`` (..., m, dv) / ``z0`` (..., m) seed the scan (zeros when None,
    i.e. a fresh sequence); every position attends to the carried prefix
    plus its own causal chunk — which is what makes prefill *resumable*:
    the state after k tokens is a valid entry point for the next chunk.
    Returns (out, s_final, z_final); out in v.dtype, state in f32.
    """
    *batch, l, m = qf.shape
    dv = v.shape[-1]
    if l % chunk:
        pad = chunk - l % chunk
        qf = jnp.pad(qf, [(0, 0)] * len(batch) + [(0, pad), (0, 0)])
        kf = jnp.pad(kf, [(0, 0)] * len(batch) + [(0, pad), (0, 0)])
        v = jnp.pad(v, [(0, 0)] * len(batch) + [(0, pad), (0, 0)])
    lp = qf.shape[-2]
    nc = lp // chunk
    qc = qf.reshape(*batch, nc, chunk, m).astype(jnp.float32)
    kc = kf.reshape(*batch, nc, chunk, m).astype(jnp.float32)
    vc = v.reshape(*batch, nc, chunk, dv).astype(jnp.float32)
    tri = jnp.tril(jnp.ones((chunk, chunk), dtype=jnp.float32))

    def step(carry, xs):
        s, z = carry
        qb, kb, vb = xs
        local = jnp.einsum("...qm,...km->...qk", qb, kb) * tri
        num = jnp.einsum("...qm,...md->...qd", qb, s) + jnp.einsum(
            "...qk,...kd->...qd", local, vb)
        den = jnp.einsum("...qm,...m->...q", qb, z) + jnp.sum(local, axis=-1)
        s = s + jnp.einsum("...km,...kd->...md", kb, vb)
        z = z + jnp.sum(kb, axis=-2)
        return (s, z), (num, den)

    if s0 is None:
        s0 = jnp.zeros((*batch, m, dv), jnp.float32)
    if z0 is None:
        z0 = jnp.zeros((*batch, m), jnp.float32)
    s0 = jnp.broadcast_to(s0.astype(jnp.float32), (*batch, m, dv))
    z0 = jnp.broadcast_to(z0.astype(jnp.float32), (*batch, m))
    qs = jnp.moveaxis(qc, len(batch), 0)
    ks = jnp.moveaxis(kc, len(batch), 0)
    vs = jnp.moveaxis(vc, len(batch), 0)
    (s_f, z_f), (nums, dens) = jax.lax.scan(step, (s0, z0), (qs, ks, vs))
    nums = jnp.moveaxis(nums, 0, len(batch)).reshape(*batch, lp, dv)
    dens = jnp.moveaxis(dens, 0, len(batch)).reshape(*batch, lp)
    out = nums / (dens[..., None] + eps)
    return out[..., :l, :].astype(v.dtype), s_f, z_f


def linear_attention_causal_chunked(qf: Array, kf: Array, v: Array,
                                    chunk: int = 256,
                                    eps: float = 1e-6) -> Array:
    """Fresh-sequence (zero initial state) chunked causal linear attention."""
    out, _, _ = linear_attention_causal_carry(qf, kf, v, chunk=chunk,
                                              eps=eps)
    return out


class LinearState(NamedTuple):
    """O(1) decode state for linear attention: S (m x dv) and z (m)."""
    s: Array   # (..., m, dv) float32
    z: Array   # (..., m)     float32

    @classmethod
    def zeros(cls, batch_shape: tuple, m: int, dv: int) -> "LinearState":
        return cls(jnp.zeros((*batch_shape, m, dv), jnp.float32),
                   jnp.zeros((*batch_shape, m), jnp.float32))


def linear_attention_prefill(qf: Array, kf: Array, v: Array,
                             chunk: int = 256,
                             eps: float = 1e-6) -> tuple[Array, LinearState]:
    """Full-sequence causal pass that also returns the final decode state."""
    out = linear_attention_causal_chunked(qf, kf, v, chunk=chunk, eps=eps)
    s = jnp.einsum("...lm,...ld->...md", kf.astype(jnp.float32),
                   v.astype(jnp.float32))
    z = jnp.sum(kf.astype(jnp.float32), axis=-2)
    return out, LinearState(s, z)


def linear_attention_decode(qf: Array, kf: Array, v: Array,
                            state: LinearState,
                            eps: float = 1e-6) -> tuple[Array, LinearState]:
    """One-token decode. qf,kf: (..., m); v: (..., dv)."""
    s = state.s + kf[..., :, None].astype(jnp.float32) * v[
        ..., None, :].astype(jnp.float32)
    z = state.z + kf.astype(jnp.float32)
    num = jnp.einsum("...m,...md->...d", qf.astype(jnp.float32), s)
    den = jnp.einsum("...m,...m->...", qf.astype(jnp.float32), z)
    out = num / (den[..., None] + eps)
    return out.astype(v.dtype), LinearState(s, z)


def sequence_parallel_state_combine(partial_states: LinearState,
                                    axis_name: str) -> LinearState:
    """SP prefill: combine per-shard prefix states with one all-reduce.

    The chunked state update is associative, so sequence-parallel prefill
    reduces to psum of partial (S, z). Used under shard_map when the
    sequence axis is sharded (beyond-paper optimization; see DESIGN §6).
    """
    return LinearState(jax.lax.psum(partial_states.s, axis_name),
                       jax.lax.psum(partial_states.z, axis_name))
