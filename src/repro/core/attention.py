"""The paper's attention mechanism as one composable entry point.

``rf_attention`` dispatches on FeatureConfig.kind:

  exact       -> softmax attention (optionally sliding-window)
  performer   -> isotropic PRF linear attention (Choromanski 2021)
  darkformer  -> data-aware PRF linear attention (this paper)
  lfk         -> learned-feature-kernel linear attention (paper baseline)
  random      -> fixed random attention weights (paper baseline)
  constant    -> uniform attention (paper baseline)

plus the serving variants (prefill / decode).

Layout: q is (B, G, Hg, L, d) — G KV groups (GQA), Hg query heads per
group; k, v are (B, G, 1, L, d). Feature params are per group:
{"w": (G, m, r), "m_mat": (G, r, d)}.

Numerical-stability contract for PRFs (exp of raw logits):
  * q features: any per-(b,g,h,position) scale cancels in num/den — we use a
    per-(b,g,h) max.
  * k features: the scale must be CONSTANT ACROSS POSITIONS to preserve the
    relative weights. Training/prefill uses one max over (L, m); decode
    carries a running max ``c`` in the state and rescales (S, z) by
    exp(c_old - c_new) when a new key exceeds it — the linear-attention
    analogue of online-softmax rescaling.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import feature_maps as fm
from repro.core import linear_attention as la
# module-level: the wrappers resolve interpret-vs-TPU once; importing
# inside the hot functions re-ran the import machinery on every trace
from repro.kernels import ops as kops

Array = jax.Array

# feature kinds with a decode-time PRF state (and hence a fused path)
PRF_KINDS = fm.PRF_KINDS


def _scale_qk(q: Array, k: Array) -> tuple[Array, Array]:
    """Absorb the 1/sqrt(d) softmax temperature symmetrically (paper fn. 2)."""
    d = q.shape[-1]
    s = d ** -0.25
    return q * s, k * s


def _raw_logits(x: Array, fparams: dict, kind: str) -> Array:
    """PRF pre-exp logits: w.x - ||x||^2/2 (iso/lfk) or w.(Mx) - ||Mx||^2/2.

    x: (B, G, H, L, d) -> (B, G, H, L, m), f32.

    Trainability contract (paper §6): the projection W is a FIXED random
    draw for performer and darkformer (stop-gradient); only the LFK
    baseline trains W directly, and only darkformer trains M (= the
    learned covariance Sigma = M^T M).
    """
    w = fparams["w"].astype(jnp.float32)              # (G, m, r)
    if kind != "lfk":
        w = jax.lax.stop_gradient(w)
    x = x.astype(jnp.float32)
    if kind == "darkformer":
        m_mat = fparams["m_mat"].astype(jnp.float32)  # (G, r, d)
        x = jnp.einsum("bghld,grd->bghlr", x, m_mat)
    elif kind not in ("performer", "lfk"):
        raise ValueError(f"unsupported feature kind {kind!r}")
    return (jnp.einsum("bghlr,gmr->bghlm", x, w)
            - 0.5 * jnp.sum(jnp.square(x), axis=-1, keepdims=True))


def _stab_max(raw: Array, enabled: bool) -> Array:
    if not enabled:
        return jnp.zeros(raw.shape[:-2] + (1, 1), raw.dtype)
    return jax.lax.stop_gradient(
        jnp.max(raw, axis=(-2, -1), keepdims=True))


def _qk_feature_pair(q, k, fparams, cfg: fm.FeatureConfig):
    """q:(B,G,Hg,L,d), k:(B,G,1,L,d) -> qf:(B,G,Hg,L,m), kf:(B,G,1,L,m)."""
    inv_sqrt_m = cfg.num_features ** -0.5
    qraw = _raw_logits(q, fparams, cfg.kind)
    kraw = _raw_logits(k, fparams, cfg.kind)
    qf = jnp.exp(qraw - _stab_max(qraw, cfg.stabilize)) * inv_sqrt_m
    kc = _stab_max(kraw, cfg.stabilize)
    kf = jnp.exp(kraw - kc) * inv_sqrt_m
    return qf, kf, kc


def _resume_qk_features(qs, ks, fparams, cfg: fm.FeatureConfig, c_in,
                        valid_mask: Optional[Array] = None):
    """Feature pair against the RUNNING k-stabilizer carried in ``c_in``
    (see module docstring): the new max folds the incoming one, and the
    carried (S, z) must be scaled by ``rescale = exp(c_in - c_new)``.
    The shared core of one-token decode and resumed chunk prefill.

    ``valid_mask`` ((B, 1, 1, L, 1) bool, or None for all-valid) marks
    ragged-row padding: masked positions contribute nothing to the
    stabilizer maxes and get zero k-features, so a padded row's state
    advances exactly as its unpadded (B=1) counterpart would.
    Returns (qf, kf, c_new, rescale)."""
    inv_sqrt_m = cfg.num_features ** -0.5
    qraw = _raw_logits(qs, fparams, cfg.kind)
    kraw = _raw_logits(ks, fparams, cfg.kind)
    if valid_mask is not None:
        neg = jnp.finfo(jnp.float32).min
        qraw_m = jnp.where(valid_mask, qraw, neg)
        kraw_m = jnp.where(valid_mask, kraw, neg)
    else:
        qraw_m, kraw_m = qraw, kraw
    qf = jnp.exp(qraw - _stab_max(qraw_m, cfg.stabilize)) * inv_sqrt_m
    if cfg.stabilize:
        c_new = jnp.maximum(c_in, _stab_max(kraw_m, True))
    else:
        # unstabilized features carry c == 0 (the init state's -inf
        # sentinel only ever zeroes an all-zero fresh state)
        c_new = jnp.zeros_like(c_in)
    rescale = jnp.exp(c_in - c_new)                    # <= 1
    kf = jnp.exp(kraw - c_new) * inv_sqrt_m
    if valid_mask is not None:
        kf = jnp.where(valid_mask, kf, 0.0)
    return qf, kf, c_new, rescale


def rf_attention(q: Array, k: Array, v: Array, fparams: Optional[dict],
                 cfg: fm.FeatureConfig, *, causal: bool = True,
                 window: Optional[int] = None, chunk: int = 256,
                 use_kernel: bool = False,
                 baseline_key: Optional[Array] = None) -> Array:
    """Training-time attention. Returns (B, G, Hg, L, dv)."""
    b, g, hg, l, _ = q.shape
    dv = v.shape[-1]
    if cfg.kind == "exact":
        qs, ks = _scale_qk(q, k)
        return la.exact_attention(qs, ks, v, causal=causal, window=window)
    if cfg.kind == "constant":
        out = la.constant_attention(v, causal=causal)
        return jnp.broadcast_to(out, (b, g, hg, l, dv))
    if cfg.kind == "random":
        assert baseline_key is not None, "random baseline needs a key"
        out = la.random_attention(baseline_key, v, causal=causal)
        return jnp.broadcast_to(out, (b, g, hg, l, dv))

    qs, ks = _scale_qk(q, k)
    qf, kf, _ = _qk_feature_pair(qs, ks, fparams, cfg)
    kf = jnp.broadcast_to(kf, (b, g, hg, l, cfg.num_features))
    vv = jnp.broadcast_to(v, (b, g, hg, l, dv))
    if not causal:
        return la.linear_attention_noncausal(qf, kf, vv, eps=cfg.eps)
    if use_kernel:
        return kops.linear_attention_causal(qf, kf, vv, eps=cfg.eps)
    return la.linear_attention_causal_chunked(qf, kf, vv, chunk=chunk,
                                              eps=cfg.eps)


class AttnServeState(NamedTuple):
    """Serving state.

    exact  — KV cache (B, G, Lmax, d) + write index. ``length`` is ()
             int32 when the whole batch decodes in lock-step, or (B,)
             int32 for per-slot lengths (continuous batching: each slot
             owns one page of the cache and writes at its own index).
    paged  — ``table`` set selects block-granular paging: ``kv_k`` /
             ``kv_v`` become SHARED page pools (n_pages, page_size, G,
             d) and ``table`` (B, max_pages) maps each row's logical
             page j to a physical pool page (page 0 is the reserved
             garbage page that masked/inactive writes land on). Rows
             can then share physical prefix pages copy-on-write — the
             prefix-cache fork path (repro/serving/prefix_cache.py).
    linear — running (S, z) plus the running k-stabilizer ``c``. All
             leaves carry a leading batch axis, so the state doubles as
             a slot pool: slot i lives at batch row i of every leaf.
    """
    kv_k: Optional[Array] = None
    kv_v: Optional[Array] = None
    length: Optional[Array] = None          # () or (B,) int32
    s: Optional[Array] = None               # (B, G, Hg, m, dv) f32
    z: Optional[Array] = None               # (B, G, Hg, m)     f32
    c: Optional[Array] = None               # (B, G, 1, 1, 1)   f32
    table: Optional[Array] = None           # (B, max_pages)    int32


def _exact_prefill_resume(qs, ks, v, state: AttnServeState,
                          window: Optional[int], out_dtype,
                          valid_len: Optional[Array] = None):
    """Append an l-token chunk to the exact KV cache and attend the chunk
    queries over the whole valid prefix. ``state.length`` is () or (B,)
    — the multi-token generalization of ``_exact_decode``.

    ``valid_len`` ((B,) int32, requires a (B,) ``length``) marks ragged
    rows: row b appends only its first ``valid_len[b]`` keys/values and
    advances its write index by ``valid_len[b]`` — the padded positions
    of a batched multi-admission prefill chunk leave no trace. The
    ragged write is a masked gather-scatter, NOT a dynamic slice: a
    padded chunk near the end of a page can have ``idx + l > lmax``,
    and dynamic_update_slice would clamp the start and shift every
    valid write."""
    l = qs.shape[-2]
    idx = state.length
    if valid_len is not None:
        # per-cache-position source index into the chunk; positions in
        # [idx, idx + valid_len) take chunk token (pos - idx), the rest
        # keep the old page contents
        lmax = state.kv_k.shape[2]
        kpos = jnp.arange(lmax)
        rel = kpos[None] - idx[:, None]                  # (B, lmax)
        keep = (rel >= 0) & (rel < valid_len[:, None])
        relc = jnp.clip(rel, 0, l - 1)[:, None, :, None]
        knew = jnp.take_along_axis(
            ks[:, :, 0], jnp.broadcast_to(relc, ks[:, :, 0].shape[:2]
                                          + (lmax, ks.shape[-1])), axis=2)
        vnew = jnp.take_along_axis(
            v[:, :, 0], jnp.broadcast_to(relc, v[:, :, 0].shape[:2]
                                         + (lmax, v.shape[-1])), axis=2)
        km = keep[:, None, :, None]
        kc = jnp.where(km, knew, state.kv_k)
        vc = jnp.where(km, vnew, state.kv_v)
        qpos_b = idx[:, None] + jnp.arange(l)[None]      # (B, l)
    elif idx.ndim == 0:
        kc = jax.lax.dynamic_update_slice_in_dim(
            state.kv_k, ks[:, :, 0], idx, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            state.kv_v, v[:, :, 0], idx, axis=2)
        qpos = idx + jnp.arange(l)                       # (l,) absolute
        qpos_b = qpos[None]                              # (1, l)
    else:
        write = jax.vmap(
            lambda cache, new, i: jax.lax.dynamic_update_slice_in_dim(
                cache, new, i, axis=1))
        kc = write(state.kv_k, ks[:, :, 0], idx)
        vc = write(state.kv_v, v[:, :, 0], idx)
        qpos_b = idx[:, None] + jnp.arange(l)[None]      # (B, l)
    lmax = kc.shape[2]
    kpos = jnp.arange(lmax)
    valid = kpos[None, None, :] <= qpos_b[:, :, None]    # (B|1, l, lmax)
    if window is not None:
        valid &= kpos[None, None, :] > qpos_b[:, :, None] - window
    vmask = valid[:, None, None]                         # (B|1,1,1,l,lmax)
    logits = jnp.einsum("bghqd,bgkd->bghqk", qs, kc).astype(jnp.float32)
    logits = jnp.where(vmask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bghqk,bgkd->bghqd", probs, vc).astype(out_dtype)
    adv = l if valid_len is None else valid_len
    return out, state._replace(kv_k=kc, kv_v=vc, length=idx + adv)


def _exact_paged_append_attend(qs, ks, v, state: AttnServeState,
                               window: Optional[int], out_dtype,
                               valid_len: Optional[Array] = None):
    """Paged-KV generalization of :func:`_exact_prefill_resume`.

    Token t of row b lands at flat pool position
    ``table[b, (length[b]+t) // ps] * ps + (length[b]+t) % ps``; masked
    (padded) positions are routed to the reserved garbage page 0, so a
    ragged batched chunk leaves no trace outside each row's own pages.
    Reads gather the row's whole table (max_pages * ps logical
    positions, unused ones masked to -inf) and apply the same
    prefix-masked softmax as the contiguous path — paged and contiguous
    streams agree to f32 rounding under identical chunk schedules, and
    paged-vs-paged is bitwise (only the physical page ids differ, which
    the gather erases). Decode is the l=1 case.

    Because rows only ever append at their own length, a physical page
    that is FULLY covered by some row's committed prefix is append-only
    immutable — which is what lets the prefix cache share prefix pages
    across forked rows and copy only the partial tail page
    (copy-on-write at fork, repro/serving/prefix_cache.py).
    """
    b, g, hg, l, dh = qs.shape
    npg, ps, gk, dhk = state.kv_k.shape
    mp = state.table.shape[1]
    idx = state.length                                   # (B,)
    pos = idx[:, None] + jnp.arange(l)[None]             # (B, l) absolute
    logical = jnp.minimum(pos // ps, mp - 1)
    phys = jnp.take_along_axis(state.table, logical, axis=1)
    flat = phys * ps + pos % ps                          # (B, l) pool pos
    if valid_len is not None:
        keep = jnp.arange(l)[None] < valid_len[:, None]
        flat = jnp.where(keep, flat, 0)                  # garbage page 0
    kf = state.kv_k.reshape(npg * ps, gk, dhk)
    vf = state.kv_v.reshape(npg * ps, gk, dhk)
    knew = jnp.moveaxis(ks[:, :, 0], 1, 2).reshape(b * l, gk, dhk)
    vnew = jnp.moveaxis(v[:, :, 0], 1, 2).reshape(b * l, gk, -1)
    kf = kf.at[flat.reshape(-1)].set(knew.astype(kf.dtype))
    vf = vf.at[flat.reshape(-1)].set(vnew.astype(vf.dtype))
    # gather each row's paged prefix back as a logically-contiguous view
    gidx = (state.table[:, :, None] * ps
            + jnp.arange(ps)[None, None]).reshape(b, mp * ps)
    kc = jnp.moveaxis(kf[gidx], 1, 2)                    # (B, G, Lc, dh)
    vc = jnp.moveaxis(vf[gidx], 1, 2)
    kpos = jnp.arange(mp * ps)
    valid = kpos[None, None, :] <= pos[:, :, None]       # (B, l, Lc)
    if window is not None:
        valid &= kpos[None, None, :] > pos[:, :, None] - window
    vmask = valid[:, None, None]                         # (B,1,1,l,Lc)
    logits = jnp.einsum("bghqd,bgkd->bghqk", qs, kc).astype(jnp.float32)
    logits = jnp.where(vmask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bghqk,bgkd->bghqd", probs, vc).astype(out_dtype)
    adv = l if valid_len is None else valid_len
    return out, state._replace(kv_k=kf.reshape(npg, ps, gk, dhk),
                               kv_v=vf.reshape(npg, ps, gk, dhk),
                               length=idx + adv)


def rf_attention_prefill(q, k, v, fparams, cfg: fm.FeatureConfig, *,
                         window: Optional[int] = None, chunk: int = 256,
                         max_len: Optional[int] = None,
                         use_kernel: bool = False,
                         state: Optional[AttnServeState] = None,
                         valid_len: Optional[Array] = None,
                         proj: Optional[dict] = None):
    """Causal pass over a prompt (chunk) + advanced serving state.

    ``state=None`` is the legacy whole-prompt entry point: the serving
    state is built from scratch and the k-stabilizer is one max over the
    whole prompt. With an incoming ``state`` the pass *resumes*: the
    chunk attends to the carried prefix, and the stabilizer becomes a
    running max with an online exp(c_old - c_new) rescale of (S, z) —
    the multi-token generalization of ``rf_attention_decode``, so a
    prompt split into chunks reproduces the whole-prompt pass to f32
    rounding (bit-exact only when the whole prompt is one chunk from a
    fresh state, which fixes the stabilizer trajectory).

    ``valid_len`` ((B,) int32, resume-only) makes the chunk ragged: row b
    advances over its first ``valid_len[b]`` positions only; padded
    positions contribute nothing to the state (masked k-features / masked
    cache writes). Outputs at padded positions are garbage by contract —
    callers gather per-row at ``valid_len - 1``.

    With ``use_kernel`` a resumed PRF chunk runs through Pallas — fully
    fused when ``proj`` carries the precomposed projection
    (``fm.precompose_projection``): ONE ``prf_fused_prefill`` megakernel
    per layer per packed chunk does projection, feature map, in-kernel
    running-max rescale, ragged ``valid_len`` masking, the causal
    carried-state scan and the (S, z, c) advance, aliasing the state in
    place. Without ``proj`` the two-stage path (jnp
    ``_resume_qk_features`` + the ``linear_attn_scan`` carry kernel) is
    kept as the oracle.
    """
    b, g, hg, l, _ = q.shape
    dv = v.shape[-1]
    if valid_len is not None and state is None:
        raise ValueError("valid_len requires an incoming serve state "
                         "(ragged rows only arise in resumed chunks)")
    if cfg.kind == "exact":
        qs, ks = _scale_qk(q, k)
        if state is not None:
            if state.table is not None:
                return _exact_paged_append_attend(qs, ks, v, state, window,
                                                  v.dtype,
                                                  valid_len=valid_len)
            return _exact_prefill_resume(qs, ks, v, state, window, v.dtype,
                                         valid_len=valid_len)
        out = la.exact_attention(qs, ks, v, causal=True, window=window)
        lmax = max_len or l
        kc = jnp.pad(ks[:, :, 0], ((0, 0), (0, 0), (0, lmax - l), (0, 0)))
        vc = jnp.pad(v[:, :, 0], ((0, 0), (0, 0), (0, lmax - l), (0, 0)))
        state = AttnServeState(kv_k=kc, kv_v=vc,
                               length=jnp.full((), l, jnp.int32))
        return out, state

    qs, ks = _scale_qk(q, k)
    if state is None:
        qf, kf, kc = _qk_feature_pair(qs, ks, fparams, cfg)
        kfb = jnp.broadcast_to(kf, (b, g, hg, l, cfg.num_features))
        vv = jnp.broadcast_to(v, (b, g, hg, l, dv))
        if use_kernel:
            out = kops.linear_attention_causal(qf, kfb, vv, eps=cfg.eps)
        else:
            out = la.linear_attention_causal_chunked(qf, kfb, vv,
                                                     chunk=chunk,
                                                     eps=cfg.eps)
        s = jnp.einsum("bghlm,bghld->bghmd", kfb.astype(jnp.float32),
                       vv.astype(jnp.float32))
        z = jnp.sum(kfb.astype(jnp.float32), axis=-2)
        return out, AttnServeState(s=s, z=z, c=kc)

    # resume: fused megakernel when the precomposed projection is in
    # hand — raw q/k go straight in, valid_len masked in-kernel, state
    # aliased in place (docs/kernels.md §Fused prefill).
    if use_kernel and proj is not None and cfg.kind in PRF_KINDS:
        out, s, z, c = kops.fused_prf_prefill(
            qs, ks[:, :, 0], v[:, :, 0], proj["a"], proj.get("m_mat"),
            state.s, state.z, state.c[:, :, 0, 0, 0], valid_len,
            stabilize=cfg.stabilize, eps=cfg.eps, chunk=chunk)
        return (out.astype(v.dtype),
                state._replace(s=s, z=z, c=c[:, :, None, None, None]))
    # resume: online rescale of the k stabilizer, then the carried-state
    # chunked scan.
    vmask = (None if valid_len is None else
             (jnp.arange(l)[None] < valid_len[:, None])
             .reshape(b, 1, 1, l, 1))
    qf, kf, c_new, rescale = _resume_qk_features(qs, ks, fparams, cfg,
                                                 state.c, valid_mask=vmask)
    kfb = jnp.broadcast_to(kf, (b, g, hg, l, cfg.num_features))
    vv = jnp.broadcast_to(v, (b, g, hg, l, dv))
    s0 = state.s * rescale
    z0 = state.z * rescale[..., 0]
    if use_kernel:
        out, s, z = kops.linear_attention_prefill_chunk(
            qf, kfb, vv, s0, z0, chunk=chunk, eps=cfg.eps)
    else:
        out, s, z = la.linear_attention_causal_carry(
            qf, kfb, vv, s0, z0, chunk=chunk, eps=cfg.eps)
    return out, AttnServeState(s=s, z=z, c=c_new)


def init_linear_serve_state(b, g, hg, m, dv) -> AttnServeState:
    return AttnServeState(
        s=jnp.zeros((b, g, hg, m, dv), jnp.float32),
        z=jnp.zeros((b, g, hg, m), jnp.float32),
        c=jnp.full((b, g, 1, 1, 1), -1e30, jnp.float32))


def _exact_decode(qs, ks, v, state: AttnServeState,
                  window: Optional[int], out_dtype):
    """Exact-attention decode step with a () or (B,) write index.

    With a (B,) ``length`` every batch row (= serving slot) appends its
    key/value at its own position and masks its own valid prefix — the
    per-slot page write of the continuous-batching engine. Exactly the
    l=1 case of the resumable prefill chunk, so there is one copy of the
    cache-write + prefix-mask + masked-softmax contract.
    """
    return _exact_prefill_resume(qs, ks, v, state, window, out_dtype)


def rf_attention_decode(q, k, v, state: AttnServeState, fparams,
                        cfg: fm.FeatureConfig, *,
                        window: Optional[int] = None,
                        use_kernel: bool = False,
                        proj: Optional[dict] = None):
    """One-token decode. q: (B,G,Hg,1,d); k,v: (B,G,1,1,d).

    ``state.length`` (exact) may be () for lock-step batches or (B,) for
    per-slot decode; the linear state is per-slot by construction. With
    ``use_kernel`` the linear path runs through Pallas — fully fused
    when ``proj`` carries the precomposed projection
    (``fm.precompose_projection``): ONE ``prf_fused_decode`` megakernel
    does projection, feature map, in-kernel stabilizer rescale, (S, z)
    update and readout with the state aliased in place. Without
    ``proj`` the legacy two-stage path (jnp ``_resume_qk_features`` +
    ``prf_decode_step``) is kept as the oracle.
    """
    b, g, hg, _, _ = q.shape
    dv = v.shape[-1]
    if cfg.kind == "exact":
        qs, ks = _scale_qk(q, k)
        if state.table is not None:
            return _exact_paged_append_attend(qs, ks, v, state, window,
                                              v.dtype)
        return _exact_decode(qs, ks, v, state, window, v.dtype)

    qs, ks = _scale_qk(q, k)
    if use_kernel and proj is not None and cfg.kind in PRF_KINDS:
        out, s, z, c = kops.fused_prf_decode(
            qs[..., 0, :], ks[:, :, 0, 0, :], v[:, :, 0, 0, :],
            proj["a"], proj.get("m_mat"), state.s, state.z,
            state.c[:, :, 0, 0, 0], stabilize=cfg.stabilize,
            eps=cfg.eps)
        return (out.astype(v.dtype)[..., None, :],
                state._replace(s=s, z=z, c=c[:, :, None, None, None]))
    # Online rescale of the k stabilizer — shared with the resumed
    # prefill chunk (decode is its one-token case).
    qf, kf, c_new, rescale = _resume_qk_features(qs, ks, fparams, cfg,
                                                 state.c)
    kfb = jnp.broadcast_to(kf[:, :, :, 0], (b, g, hg, cfg.num_features))
    vv = jnp.broadcast_to(v[:, :, :, 0], (b, g, hg, dv))
    qf1 = qf[..., 0, :]                            # (B,G,Hg,m)
    if use_kernel:
        out, s, z = kops.linear_attention_decode_step(
            qf1, kfb, vv.astype(jnp.float32), state.s, state.z,
            rescale[..., 0, 0], eps=cfg.eps)
        return (out.astype(v.dtype)[..., None, :],
                state._replace(s=s, z=z, c=c_new))
    s = state.s * rescale + (
        kfb[..., :, None] * vv[..., None, :].astype(jnp.float32))
    z = state.z * rescale[..., 0] + kfb
    num = jnp.einsum("bghm,bghmd->bghd", qf1, s)
    den = jnp.einsum("bghm,bghm->bgh", qf1, z)
    out = (num / (den[..., None] + cfg.eps)).astype(v.dtype)
    return out[..., None, :], state._replace(s=s, z=z, c=c_new)
