"""Core: the paper's contribution — data-aware random-feature attention."""
from repro.core.feature_maps import (FeatureConfig, FEATURE_KINDS,
                                     gaussian_projection,
                                     orthogonal_projection, draw_projection,
                                     init_feature_params, whitening_init)
from repro.core.attention import (rf_attention, rf_attention_prefill,
                                  rf_attention_decode, AttnServeState,
                                  init_linear_serve_state)
from repro.core.linear_attention import (
    exact_attention, linear_attention_noncausal,
    linear_attention_causal_naive, linear_attention_causal_chunked,
    linear_attention_prefill, linear_attention_decode, LinearState,
    sequence_parallel_state_combine)
from repro.core import variance
from repro.core import calibration
