"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.

[hf:ibm-granite family] 32L d_model=1536 24H (GQA kv=8, d_head=64)
per-expert d_ff=512, vocab=49155.
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig, MoEConfig


# Sharding: 40 experts don't divide the 16-way model axis, and this
# geometry (d_model=1536, d_ff=512/expert) prefers d-over-data expert
# weights + classic megatron attention specs — chosen by the §Perf
# iteration log (EXPERIMENTS.md), 2.4x better bound than the global rules.
_SHARDING = (
    (r"\['ffn'\]\['w_gate'\]$", (None, "data", "model")),
    (r"\['ffn'\]\['w_up'\]$",   (None, "data", "model")),
    (r"\['ffn'\]\['w_out'\]$",  (None, "model", "data")),
    (r"\['attn'\]\['w[qkv]'\]$", ("data", "model")),
    (r"\['attn'\]\['wo'\]$",    ("model", "data")),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
        n_kv=8, d_head=64, d_ff=512, vocab=49_155, attn=DEFAULT_ATTN,
        moe=MoEConfig(num_experts=40, top_k=8, d_ff=512),
        tie_embeddings=True, dtype="bfloat16",
        sharding_overrides=_SHARDING)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=32, vocab=256,
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32),
        tie_embeddings=True, remat="none")
