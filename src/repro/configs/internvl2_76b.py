"""internvl2-76b — VLM: InternViT frontend (STUB) + LM backbone.

[arXiv:2404.16821] backbone 80L d_model=8192 64H (GQA kv=8, d_head=128)
d_ff=28672 vocab=128256. Per the brief, the vision frontend is a stub:
input_specs provides precomputed patch embeddings (B, 256, d_model).
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", n_layers=80, d_model=8192, n_heads=64,
        n_kv=8, d_head=128, d_ff=28_672, vocab=128_256, attn=DEFAULT_ATTN,
        modality="vlm", num_patches=256, mlp_kind="swiglu",
        tie_embeddings=False, dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv=2, d_head=16, d_ff=128, vocab=256, modality="vlm",
        num_patches=8,
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        tie_embeddings=False, remat="none")
