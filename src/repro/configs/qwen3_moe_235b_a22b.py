"""qwen3-moe-235b-a22b — 128-expert top-8 MoE with qk-norm.

[hf:Qwen/Qwen3-30B-A3B family] 94L d_model=4096 64H (GQA kv=4, d_head=128)
per-expert d_ff=1536, vocab=151936.
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
        n_kv=4, d_head=128, d_ff=1536, vocab=151_936, attn=DEFAULT_ATTN,
        qk_norm=True, rope_theta=1e6,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff=1536),
        tie_embeddings=False, dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=32, vocab=256, qk_norm=True,
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32),
        tie_embeddings=False, remat="none")
