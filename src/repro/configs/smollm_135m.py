"""smollm-135m — llama-arch small dense GQA.

[hf:HuggingFaceTB/SmolLM-135M] 30L d_model=576 9H (GQA kv=3, d_head=64)
d_ff=1536 vocab=49152.
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", n_layers=30, d_model=576, n_heads=9, n_kv=3,
        d_head=64, d_ff=1536, vocab=49_152, attn=DEFAULT_ATTN,
        mlp_kind="swiglu", tie_embeddings=True, dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-smoke", n_layers=3, d_model=48, n_heads=3,
        n_kv=3, d_head=16, d_ff=96, vocab=256,
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        tie_embeddings=True, remat="none")
