"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (MQA kv=1, d_head=256)
d_ff=7680 (GeGLU) vocab=256000, local-attention window 2048.
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", n_layers=26, d_model=2560, n_heads=10,
        n_kv=1, d_head=256, d_ff=7680, vocab=256_000,
        block_pattern=("rec", "rec", "local"), window=2048,
        mlp_kind="geglu", attn=DEFAULT_ATTN, rope_theta=10_000.0,
        d_rnn=2560, embed_scale=True, tie_embeddings=True,
        logit_softcap=30.0, dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv=1, d_head=16, d_ff=128, vocab=256,
        block_pattern=("rec", "rec", "local"), window=16,
        mlp_kind="geglu", attn=DEFAULT_ATTN.__class__(
            kind="darkformer", num_features=32),
        d_rnn=64, embed_scale=True, tie_embeddings=True, remat="none")
