"""Architecture registry + per-(arch x shape) input specs for the dry-run."""
from __future__ import annotations

import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, darkify
from repro.models import ModelConfig

ARCHS = [
    "recurrentgemma-2b",
    "smollm-135m",
    "granite-8b",
    "qwen3-32b",
    "yi-34b",
    "rwkv6-7b",
    "granite-moe-3b-a800m",
    "qwen3-moe-235b-a22b",
    "internvl2-76b",
    "hubert-xlarge",
    "darkformer-2b",           # the paper's own model (not an assigned cell)
]

ASSIGNED = ARCHS[:10]


def _module(name: str):
    return importlib.import_module(
        "repro.configs." + name.replace("-", "_"))


def get_config(name: str, reduced: bool = False, **overrides) -> ModelConfig:
    mod = _module(name)
    cfg = mod.reduced() if reduced else mod.config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def cell_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """Whether an (arch x shape) cell applies, and why not if skipped."""
    kind = SHAPES[shape_name]["kind"]
    if not cfg.causal and kind == "decode":
        return False, "encoder-only arch: no decode step"
    if shape_name == "long_500k" and cfg.attn.kind == "exact" and \
            any(k in ("attn", "local") for k in cfg.block_pattern):
        return False, ("500k decode with exact full attention skipped; "
                       "run with a PRF kernel (the paper's point)")
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str,
                per_host_batch: Optional[int] = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    Weak-type-correct, shardable, no device allocation (the dry-run
    contract). For 'decode' kinds this is the {token} input; the serving
    state is built separately via serve_state_specs_for.
    """
    sh = SHAPES[shape_name]
    b = per_host_batch or sh["global_batch"]
    l = sh["seq_len"]
    kind = sh["kind"]
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b,), i32)}
    if cfg.modality == "audio":
        d = {"frames": jax.ShapeDtypeStruct((b, l, cfg.d_model), f),
             "mask": jax.ShapeDtypeStruct((b, l), jnp.bool_)}
        if kind == "train":
            d["labels"] = jax.ShapeDtypeStruct((b, l), i32)
        return d
    if cfg.modality == "vlm":
        lt = l - cfg.num_patches
        d = {"tokens": jax.ShapeDtypeStruct((b, lt), i32),
             "patch_embeds": jax.ShapeDtypeStruct(
                 (b, cfg.num_patches, cfg.d_model), f)}
        if kind == "train":
            d["labels"] = jax.ShapeDtypeStruct((b, lt), i32)
        return d
    d = {"tokens": jax.ShapeDtypeStruct((b, l), i32)}
    if kind == "train":
        d["labels"] = jax.ShapeDtypeStruct((b, l), i32)
    return d
