"""Shared config helpers + the shape-cell table assigned to this paper."""
from __future__ import annotations

import dataclasses

from repro.core.feature_maps import FeatureConfig
from repro.models import ModelConfig, MoEConfig

# The four assigned input-shape cells (LM family).
SHAPES = {
    "train_4k":    dict(seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524_288, global_batch=1,   kind="decode"),
}

DEFAULT_ATTN = FeatureConfig(kind="darkformer", num_features=256,
                             orthogonal=True)


def darkify(cfg: ModelConfig, kind: str = "darkformer",
            num_features: int = 256) -> ModelConfig:
    """Switch a config's attention kernel (exact <-> PRF variants)."""
    return dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, kind=kind,
                                      num_features=num_features))
