"""darkformer-2b — the paper's own model: Gemma-2B with PRF attention.

Gemma-2B geometry [arXiv:2403.08295]: 18L d_model=2048 8H (MQA kv=1,
d_head=256) d_ff=16384 (GeGLU) vocab=256000, with the softmax kernel
replaced by the DARKFormer data-aware PRF (the paper's §6 setup).
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="darkformer-2b", n_layers=18, d_model=2048, n_heads=8,
        n_kv=1, d_head=256, d_ff=16_384, vocab=256_000, attn=DEFAULT_ATTN,
        mlp_kind="geglu", embed_scale=True, tie_embeddings=True,
        dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="darkformer-2b-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv=1, d_head=16, d_ff=128, vocab=256, mlp_kind="geglu",
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        embed_scale=True, tie_embeddings=True, remat="none")
