"""rwkv6-7b — "Finch", attention-free data-dependent-decay recurrence.

[arXiv:2404.05892; hf] 32L d_model=4096 (64 heads of 64) d_ff=14336
vocab=65536. The paper's PRF technique is inapplicable (no softmax kernel);
see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", n_layers=32, d_model=4096, n_heads=64, n_kv=64,
        d_head=64, d_ff=14_336, vocab=65_536,
        block_pattern=("rwkv",), attn=DEFAULT_ATTN,
        tie_embeddings=False, dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_head=16, d_ff=128, vocab=256, block_pattern=("rwkv",),
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        tie_embeddings=False, remat="none")
