"""qwen3-32b — dense GQA with qk-norm.

[hf:Qwen/Qwen3-8B family] 64L d_model=5120 64H (GQA kv=8, d_head=128)
d_ff=25600 vocab=151936, qk_norm.
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", n_layers=64, d_model=5120, n_heads=64, n_kv=8,
        d_head=128, d_ff=25_600, vocab=151_936, attn=DEFAULT_ATTN,
        qk_norm=True, rope_theta=1e6, mlp_kind="swiglu",
        tie_embeddings=False, dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256, qk_norm=True,
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        tie_embeddings=False, remat="none")
