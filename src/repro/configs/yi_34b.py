"""yi-34b — llama-arch dense GQA.

[arXiv:2403.04652; hf] 60L d_model=7168 56H (GQA kv=8, d_head=128)
d_ff=20480 vocab=64000.
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv=8,
        d_head=128, d_ff=20_480, vocab=64_000, attn=DEFAULT_ATTN,
        rope_theta=5e6, mlp_kind="swiglu", tie_embeddings=False,
        dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke", n_layers=2, d_model=56, n_heads=7, n_kv=1,
        d_head=16, d_ff=112, vocab=256,
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        tie_embeddings=False, remat="none")
