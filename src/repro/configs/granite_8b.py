"""granite-8b — llama-arch dense GQA, code model.

[arXiv:2405.04324; hf] 36L d_model=4096 32H (GQA kv=8, d_head=128)
d_ff=14336 vocab=49152.
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", n_layers=36, d_model=4096, n_heads=32, n_kv=8,
        d_head=128, d_ff=14_336, vocab=49_152, attn=DEFAULT_ATTN,
        mlp_kind="swiglu", tie_embeddings=False, dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256,
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        tie_embeddings=False, remat="none")
