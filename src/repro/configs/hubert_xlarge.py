"""hubert-xlarge — encoder-only audio transformer (w2v2 arch).

[arXiv:2106.07447] 48L d_model=1280 16H (MHA kv=16, d_head=80) d_ff=5120
vocab=504 (masked-frame cluster prediction). The conv waveform frontend is
a STUB: input_specs provides precomputed frame embeddings (B, L, d_model).
Encoder-only: no decode shapes (noncausal PRF attention = the O(Lmd)
two-matmul form).
"""
from repro.configs.base import DEFAULT_ATTN
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", n_layers=48, d_model=1280, n_heads=16,
        n_kv=16, d_head=80, d_ff=5120, vocab=504, attn=DEFAULT_ATTN,
        causal=False, modality="audio", norm_kind="layernorm",
        mlp_kind="gelu", tie_embeddings=False, dtype="bfloat16")


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv=4, d_head=16, d_ff=128, vocab=64, causal=False,
        modality="audio", norm_kind="layernorm", mlp_kind="gelu",
        attn=DEFAULT_ATTN.__class__(kind="darkformer", num_features=32),
        tie_embeddings=False, remat="none")
