"""Jit'd public wrappers for the Pallas kernels, with autodiff.

Forward = Pallas kernel; backward = VJP of the pure-jnp oracle (exact same
math, so gradients are correct and the kernel stays forward-only). On this
CPU container the kernels run with interpret=True; on TPU they compile.
``repro.kernels.USE_INTERPRET`` is resolved once from the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.linear_attn_scan import (linear_attention_causal_fwd,
                                            linear_attention_causal_carry_fwd)
from repro.kernels.prf_featmap import prf_featmap_fwd

Array = jax.Array


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Chunked causal linear attention
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _lin_attn(qf: Array, kf: Array, v: Array, chunk: int, eps: float):
    n = qf.shape[:-2]
    l, m = qf.shape[-2:]
    dv = v.shape[-1]
    qf2 = qf.reshape(-1, l, m)
    kf2 = kf.reshape(-1, l, m)
    v2 = v.reshape(-1, l, dv)
    out = linear_attention_causal_fwd(qf2, kf2, v2, chunk=chunk, eps=eps,
                                      interpret=_use_interpret())
    return out.reshape(*n, l, dv)


def _lin_attn_fwd(qf, kf, v, chunk, eps):
    return _lin_attn(qf, kf, v, chunk, eps), (qf, kf, v)


def _lin_attn_bwd(chunk, eps, res, g):
    qf, kf, v = res
    n = qf.shape[:-2]
    l, m = qf.shape[-2:]
    dv = v.shape[-1]

    def f(qf_, kf_, v_):
        return _ref.linear_attention_causal_ref(
            qf_.reshape(-1, l, m), kf_.reshape(-1, l, m),
            v_.reshape(-1, l, dv), eps=eps).reshape(*n, l, dv)

    _, vjp = jax.vjp(f, qf, kf, v)
    return vjp(g)


_lin_attn.defvjp(_lin_attn_fwd, _lin_attn_bwd)


def linear_attention_causal(qf: Array, kf: Array, v: Array, *,
                            chunk: int = 256, eps: float = 1e-6) -> Array:
    """Causal PRF attention via the Pallas scan kernel. (..., L, m) x
    (..., L, dv) -> (..., L, dv); differentiable (oracle-VJP backward)."""
    return _lin_attn(qf, kf, v, chunk, eps)


def linear_attention_prefill_chunk(qf: Array, kf: Array, v: Array,
                                   s: Array, z: Array, *,
                                   chunk: int = 256, eps: float = 1e-6
                                   ) -> tuple[Array, Array, Array]:
    """Advance a PRF prefix state over a prompt chunk via the Pallas scan.

    qf, kf: (..., L, m); v: (..., L, dv); s: (..., m, dv); z: (..., m) —
    leading dims are independent (batch, group, head) rows and get
    flattened. Forward-only (serving-side chunked prefill; no VJP).
    Returns (out (..., L, dv), s_new, z_new); state in f32.
    """
    lead = qf.shape[:-2]
    l, m = qf.shape[-2:]
    dv = v.shape[-1]
    out, s_new, z_new = linear_attention_causal_carry_fwd(
        qf.reshape(-1, l, m), kf.reshape(-1, l, m), v.reshape(-1, l, dv),
        jnp.broadcast_to(s, (*lead, m, dv)).reshape(-1, m, dv)
        .astype(jnp.float32),
        jnp.broadcast_to(z, (*lead, m)).reshape(-1, m).astype(jnp.float32),
        chunk=chunk, eps=eps, interpret=_use_interpret())
    return (out.reshape(*lead, l, dv), s_new.reshape(*lead, m, dv),
            z_new.reshape(*lead, m))


# ---------------------------------------------------------------------------
# One-token PRF decode step (serving)
# ---------------------------------------------------------------------------

from repro.kernels.prf_decode_step import prf_decode_step_fwd  # noqa: E402


def linear_attention_decode_step(qf: Array, kf: Array, v: Array,
                                 s: Array, z: Array, rescale: Array, *,
                                 eps: float = 1e-6, block_b: int = 8):
    """Advance the PRF serving state by one token via the Pallas kernel.

    qf, kf, z: (..., m); v: (..., dv); s: (..., m, dv); rescale: (...,)
    — leading dims are independent (batch, group, head) slots and get
    flattened. Forward-only (decode is inference; no VJP registered).
    Returns (out (..., dv), s_new, z_new), f32.
    """
    lead = qf.shape[:-1]
    m = qf.shape[-1]
    dv = v.shape[-1]
    out, s_new, z_new = prf_decode_step_fwd(
        qf.reshape(-1, m), kf.reshape(-1, m), v.reshape(-1, dv),
        s.reshape(-1, m, dv), z.reshape(-1, m),
        jnp.broadcast_to(rescale, lead).reshape(-1, 1),
        eps=eps, block_b=block_b, interpret=_use_interpret())
    return (out.reshape(*lead, dv), s_new.reshape(*lead, m, dv),
            z_new.reshape(*lead, m))


# ---------------------------------------------------------------------------
# Fused data-aligned decode megakernel (serving)
# ---------------------------------------------------------------------------

from repro.kernels.prf_fused_decode import prf_fused_decode_fwd  # noqa: E402


def fused_prf_decode(q: Array, k: Array, v: Array, a: Array,
                     m_mat: Array | None, s: Array, z: Array, c: Array,
                     *, stabilize: bool = True, eps: float = 1e-6,
                     block_b: int = 8):
    """One-token PRF decode fully fused: raw scaled q/k in, advanced
    (S, z, c) pool out, with the projection/featmap/stabilizer/update/
    readout chain in one kernel and the pool aliased in place.

    q: (B, G, Hg, d); k, v: (B, G, d|dv); a: (G, d, m) precomposed
    (W M)^T (see ``feature_maps.precompose_projection``); m_mat:
    (G, r, d) or None; s: (B, G, Hg, m, dv); z: (B, G, Hg, m);
    c: (B, G). Forward-only (decode is inference; no VJP).
    Returns (out (B, G, Hg, dv) f32, s_new, z_new, c_new (B, G)).
    """
    return prf_fused_decode_fwd(
        q, k, v.astype(jnp.float32), a, m_mat, s, z, c,
        stabilize=stabilize, eps=eps, block_b=block_b,
        interpret=_use_interpret())


# ---------------------------------------------------------------------------
# Fused data-aligned prefill megakernel (serving)
# ---------------------------------------------------------------------------

from repro.kernels.prf_fused_prefill import prf_fused_prefill_fwd  # noqa: E402


def fused_prf_prefill(q: Array, k: Array, v: Array, a: Array,
                      m_mat: Array | None, s: Array, z: Array, c: Array,
                      valid_len: Array | None = None, *,
                      stabilize: bool = True, eps: float = 1e-6,
                      chunk: int = 256, block_b: int = 1):
    """One packed prefill chunk fully fused: raw scaled q/k in, chunk
    outputs plus the advanced resumable (S, z, c) out, with the
    projection/featmap/running-max stabilizer/causal scan/state advance
    chain in one kernel per layer per chunk, ragged ``valid_len`` rows
    masked in-kernel, and the state aliased in place.

    q: (B, G, Hg, L, d); k, v: (B, G, L, d|dv); a: (G, d, m)
    precomposed (W M)^T (see ``feature_maps.precompose_projection``);
    m_mat: (G, r, d) or None; s: (B, G, Hg, m, dv); z: (B, G, Hg, m);
    c: (B, G); valid_len: (B,) int32 or None. Forward-only (serving-
    side prefill; no VJP). Returns (out (B, G, Hg, L, dv) in v.dtype,
    s_new, z_new, c_new (B, G)), state in f32.
    """
    return prf_fused_prefill_fwd(
        q, k, v, a, m_mat, s, z, c, valid_len,
        stabilize=stabilize, eps=eps, chunk=chunk, block_b=block_b,
        interpret=_use_interpret())


# ---------------------------------------------------------------------------
# Fused PRF feature map
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _featmap(x, m_mat, w, c, block_n):
    shape = x.shape
    out = prf_featmap_fwd(x.reshape(-1, shape[-1]), m_mat, w, c,
                          block_n=block_n, interpret=_use_interpret())
    return out.reshape(*shape[:-1], w.shape[0])


def _featmap_fwd(x, m_mat, w, c, block_n):
    return _featmap(x, m_mat, w, c, block_n), (x, m_mat, w, c)


def _featmap_bwd(block_n, res, g):
    x, m_mat, w, c = res
    shape = x.shape

    def f(x_, m_, w_, c_):
        return _ref.prf_featmap_ref(x_.reshape(-1, shape[-1]), m_, w_,
                                    c_).reshape(*shape[:-1], w_.shape[0])

    _, vjp = jax.vjp(f, x, m_mat, w, c)
    return vjp(g)


_featmap.defvjp(_featmap_fwd, _featmap_bwd)


def prf_featmap(x: Array, m_mat: Array | None, w: Array,
                c: Array | float = 0.0, *, block_n: int = 256) -> Array:
    """Fused phi(x) = exp(W Mx - ||Mx||^2/2 - c)/sqrt(m). Differentiable."""
    c = jnp.asarray(c, jnp.float32)
    if m_mat is None:
        # custom_vjp can't take None leaves; isotropic uses identity fold.
        return _featmap_iso(x, w, c, block_n)
    return _featmap(x, m_mat, w, c, block_n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _featmap_iso(x, w, c, block_n):
    shape = x.shape
    out = prf_featmap_fwd(x.reshape(-1, shape[-1]), None, w, c,
                          block_n=block_n, interpret=_use_interpret())
    return out.reshape(*shape[:-1], w.shape[0])


def _featmap_iso_fwd(x, w, c, block_n):
    return _featmap_iso(x, w, c, block_n), (x, w, c)


def _featmap_iso_bwd(block_n, res, g):
    x, w, c = res
    shape = x.shape

    def f(x_, w_, c_):
        return _ref.prf_featmap_ref(x_.reshape(-1, shape[-1]), None, w_,
                                    c_).reshape(*shape[:-1], w_.shape[0])

    _, vjp = jax.vjp(f, x, w, c)
    return vjp(g)


_featmap_iso.defvjp(_featmap_iso_fwd, _featmap_iso_bwd)


# ---------------------------------------------------------------------------
# Chunked WKV-6 recurrence
# ---------------------------------------------------------------------------

from repro.kernels.wkv6_scan import wkv6_fwd as _wkv6_fwd  # noqa: E402


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _wkv6(r, k, v, w, u, chunk):
    n = r.shape[:-2]
    l, dh = r.shape[-2:]
    out = _wkv6_fwd(r.reshape(-1, l, dh), k.reshape(-1, l, dh),
                    v.reshape(-1, l, dh), w.reshape(-1, l, dh), u,
                    chunk=chunk, interpret=_use_interpret())
    return out.reshape(*n, l, dh)


def _wkv6_vjp_fwd(r, k, v, w, u, chunk):
    return _wkv6(r, k, v, w, u, chunk), (r, k, v, w, u)


def _wkv6_vjp_bwd(chunk, res, g):
    r, k, v, w, u = res
    n = r.shape[:-2]
    l, dh = r.shape[-2:]

    def f(r_, k_, v_, w_, u_):
        s0 = jnp.zeros((r_.reshape(-1, l, dh).shape[0], dh, dh),
                       jnp.float32)
        o, _ = _ref.wkv6_ref(r_.reshape(-1, l, dh), k_.reshape(-1, l, dh),
                             v_.reshape(-1, l, dh), w_.reshape(-1, l, dh),
                             u_, s0)
        return o.reshape(*n, l, dh)

    _, vjp = jax.vjp(f, r, k, v, w, u)
    return vjp(g)


_wkv6.defvjp(_wkv6_vjp_fwd, _wkv6_vjp_bwd)


def wkv6(r, k, v, w, u, *, chunk: int = 256):
    """Chunked RWKV-6 WKV via the Pallas kernel; oracle-VJP backward."""
    return _wkv6(r, k, v, w, u, chunk)
