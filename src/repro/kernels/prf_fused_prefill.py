"""Pallas TPU megakernel: fused data-aligned PRF prefill chunk.

The prefill twin of ``prf_fused_decode``: ONE kernel per layer per
packed (P, L) chunk that takes RAW scaled q/k/v (d-dim, the 1/sqrt(d)
temperature pre-absorbed), the precomposed data-aligned projection
``A = (W M)^T`` (plain ``W^T`` for the isotropic Performer/LFK kinds),
the per-row ragged ``valid_len`` of the token-budget packer, and the
carried ``AttnServeState`` (S, z, c), and fuses the whole resumable
prefill pass in VMEM — per (row-block, KV-group, chunk) grid step:

    qraw = q A − ‖Mq‖²/2          kraw = k A − ‖Mk‖²/2
    c'   = max(c, max_{valid,m} kraw)    ρ = exp(c − c')
    qf   = exp(qraw − max_{valid,m} qraw)/√m
    kf   = [pos < valid_len] · exp(kraw − c')/√m
    out  = (qf·(ρS) + tril(qf kfᵀ)·v) / (qf·(ρz) + Σ tril(qf kfᵀ) + ε)
    S'   = ρS + kfᵀv              z' = ρz + Σ_T kf

replacing the two-stage prefill path (jnp ``_resume_qk_features`` +
``linear_attn_scan`` carry kernel): the (N, L, m) feature tensors never
exist in HBM, the running-max k-stabilizer rescale happens while S is
already resident for the rank-1 chunk update, and
``input_output_aliases`` writes the incoming state pool IN PLACE so a
resumed chunk never reallocates pool-sized (S, z, c) buffers.

Ragged masking lives IN-KERNEL: a row's positions at or past its
``valid_len`` contribute nothing to the chunk's k-stabilizer max and
get zero k-features, so they leave no trace in (S, z, c) — the contract
that lets the serving engine pad several staged admissions into one
batched call. Outputs at padded positions are garbage by contract
(callers gather per-row at ``valid_len − 1``), exactly as in the jnp
path.

Grid: (row blocks, G, L/T chunks) — rows and KV groups parallel, the
chunk axis sequential ("arbitrary") so the (S, z, c) output blocks act
as the VMEM-resident carry: initialized from the aliased state inputs
at chunk 0, revisited every sequential step, flushed to HBM once when
the row/group block retires. Row blocks never pad (``_block_divisor``,
same reason as decode: a padded copy would be the pool-sized
allocation the aliasing removes).

GQA: k-features are computed ONCE per KV group per chunk and shared by
the Hg query heads; the per-head work (tril local attention + state
update) is a static unroll over (row, head) of plain 2-D MXU matmuls.

VMEM per grid step (f32) is dominated by the S carry block
``block_b·Hg·m·dv`` plus the chunk features ``block_b·(Hg+1)·T·m``:
for block_b = 1, Hg = 8, m = 256, dv = 128, T = 256 that is
~1 MB + ~2.4 MB of the ~16 MB/core — grow ``block_b`` only for small
(Hg, m, T) geometries.

On non-TPU backends the wrapper in ``repro.kernels.ops`` runs this with
interpret=True (same numerics, no Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import compiler_params
from repro.kernels.prf_fused_decode import _block_divisor, _featurize

Array = jax.Array

_NEG = float(jnp.finfo(jnp.float32).min)


def _kernel(q_ref, k_ref, v_ref, a_ref, m_ref, vl_ref, c_ref, s_ref,
            z_ref, o_ref, so_ref, zo_ref, co_ref, *, stabilize: bool,
            eps: float):
    ci = pl.program_id(2)
    tb, _, hg, t, d = q_ref.shape
    m = a_ref.shape[-1]
    dv = v_ref.shape[-1]
    inv_sqrt_m = m ** -0.5
    f32 = jnp.float32

    # chunk 0 seeds the carry: the (S, z, c) OUTPUT blocks are revisited
    # by every sequential chunk step (their index maps ignore ci), so
    # they live in VMEM for the whole row/group visit and double as the
    # carried state; the aliased inputs are only ever read here.
    @pl.when(ci == 0)
    def _init():
        so_ref[...] = s_ref[...].astype(f32)
        zo_ref[...] = z_ref[...].astype(f32)
        co_ref[...] = c_ref[...].astype(f32)

    q = q_ref[...].astype(f32).reshape(tb * hg * t, d)
    k = k_ref[...].astype(f32).reshape(tb * t, d)
    v = v_ref[...].astype(f32)                           # (Tb, 1, T, dv)
    a = a_ref[0].astype(f32)                             # (d, m)
    m_mat = None if m_ref is None else m_ref[0].astype(f32)

    qraw = _featurize(q, a, m_mat).reshape(tb, hg, t, m)
    kraw = _featurize(k, a, m_mat).reshape(tb, t, m)     # ONCE per group

    # ragged valid_len mask: absolute chunk positions vs per-row length.
    # Wrapper L-padding lands past every valid_len, so one mask covers
    # both the packer's ragged rows and the pow-2 tail padding.
    pos = ci * t + jax.lax.broadcasted_iota(jnp.int32, (tb, t), 1)
    valid = pos < vl_ref[...]                            # (Tb, T)
    kraw_m = jnp.where(valid[:, :, None], kraw, _NEG)

    c_old = co_ref[...]                                  # (Tb, 1) carry
    if stabilize:
        # running max over the carried c and this chunk's VALID key
        # logits; masked rows advance c by nothing (max of _NEG sentinels
        # never beats a real carry) and rho stays 1.
        mk = jnp.max(kraw_m, axis=(1, 2)).reshape(tb, 1)
        c_new = jnp.maximum(c_old, mk)
        rho = jnp.exp(c_old - c_new)                     # (Tb, 1), <= 1
        kf = jnp.exp(kraw - c_new[:, :, None]) * inv_sqrt_m
        qraw_m = jnp.where(valid[:, None, :, None], qraw, _NEG)
        qf = jnp.exp(qraw - jnp.max(qraw_m, axis=(2, 3), keepdims=True)) \
            * inv_sqrt_m
    else:
        # unstabilized features carry c == 0 (the init state's -1e30
        # sentinel only ever zeroes an all-zero fresh state)
        c_new = jnp.zeros_like(c_old)
        rho = jnp.exp(c_old)
        kf = jnp.exp(kraw) * inv_sqrt_m
        qf = jnp.exp(qraw) * inv_sqrt_m
    kf = jnp.where(valid[:, :, None], kf, 0.0)           # masked -> 0

    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    tril = row >= col

    # static unroll over (row, head): every matmul is 2-D (MXU-shaped);
    # the kfᵀv chunk update and Σkf are shared across the Hg heads.
    for b in range(tb):
        kf_b = kf[b]                                     # (T, m)
        v_b = v[b, 0]                                    # (T, dv)
        rho_b = rho[b, 0]
        ds = jax.lax.dot_general(kf_b, v_b, (((0,), (0,)), ((), ())),
                                 preferred_element_type=f32)  # (m, dv)
        dz = jnp.sum(kf_b, axis=0)                       # (m,)
        for h in range(hg):
            qf_bh = qf[b, h]                             # (T, m)
            s_old = so_ref[b, 0, h] * rho_b              # (m, dv)
            z_old = zo_ref[b, 0, h] * rho_b              # (m,)
            local = jax.lax.dot_general(
                qf_bh, kf_b, (((1,), (1,)), ((), ())),
                preferred_element_type=f32)              # (T, T)
            local = jnp.where(tril, local, 0.0)
            num = (jnp.dot(qf_bh, s_old, preferred_element_type=f32)
                   + jnp.dot(local, v_b, preferred_element_type=f32))
            den = (jnp.dot(qf_bh, z_old[:, None],
                           preferred_element_type=f32)[:, 0]
                   + jnp.sum(local, axis=1))
            o_ref[b, 0, h] = (num / (den[:, None] + eps)) \
                .astype(o_ref.dtype)
            so_ref[b, 0, h] = s_old + ds
            zo_ref[b, 0, h] = z_old + dz
    co_ref[...] = c_new


def _no_mmat_kernel(kernel, q_ref, k_ref, v_ref, a_ref, vl_ref, c_ref,
                    s_ref, z_ref, *out_refs, **kw):
    """Isotropic variant: no m_mat operand; the norm uses x itself."""
    kernel(q_ref, k_ref, v_ref, a_ref, None, vl_ref, c_ref, s_ref,
           z_ref, *out_refs, **kw)


def prf_fused_prefill_fwd(q: Array, k: Array, v: Array, a: Array,
                          m_mat: Array | None, s: Array, z: Array,
                          c: Array, valid_len: Array | None = None, *,
                          stabilize: bool = True, eps: float = 1e-6,
                          chunk: int = 256, block_b: int = 1,
                          interpret: bool = False):
    """Advance a (B, G)-state pool over a packed L-token chunk, fused.

    q: (B, G, Hg, L, d); k, v: (B, G, L, d|dv); a: (G, d, m);
    m_mat: (G, r, d) or None (isotropic); s: (B, G, Hg, m, dv) f32;
    z: (B, G, Hg, m) f32; c: (B, G) f32 running k-stabilizer;
    valid_len: (B,) int32 ragged row lengths (None = all rows full).

    Returns (out (B, G, Hg, L, dv) in v.dtype, s_new, z_new, c_new)
    with the state outputs ALIASED to the input buffers (in-place pool
    update under jit when the caller donates the pool). L is padded to
    a multiple of ``chunk`` internally; the pad is masked like ragged
    padding and sliced off the output.
    """
    b, g, hg, l, d = q.shape
    m = a.shape[-1]
    dv = v.shape[-1]
    t = min(chunk, l)
    pad = (-l) % t
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // t
    vl = (jnp.full((b,), l, jnp.int32) if valid_len is None
          else valid_len.astype(jnp.int32)).reshape(b, 1)
    tb = _block_divisor(b, block_b)
    grid = (b // tb, g, nc)

    in_specs = [
        pl.BlockSpec((tb, 1, hg, t, d), lambda i, gi, ci: (i, gi, 0, ci,
                                                           0)),
        pl.BlockSpec((tb, 1, t, d), lambda i, gi, ci: (i, gi, ci, 0)),
        pl.BlockSpec((tb, 1, t, dv), lambda i, gi, ci: (i, gi, ci, 0)),
        pl.BlockSpec((1, d, m), lambda i, gi, ci: (gi, 0, 0)),
    ]
    inputs = [q, k, v, a]
    if m_mat is not None:
        r = m_mat.shape[-2]
        in_specs.append(pl.BlockSpec((1, r, d),
                                     lambda i, gi, ci: (gi, 0, 0)))
        inputs.append(m_mat)
        kernel = _kernel
    else:
        kernel = functools.partial(_no_mmat_kernel, _kernel)
    in_specs.append(pl.BlockSpec((tb, 1), lambda i, gi, ci: (i, 0)))
    inputs.append(vl)
    n_state = len(inputs)
    in_specs += [
        pl.BlockSpec((tb, 1), lambda i, gi, ci: (i, gi)),
        pl.BlockSpec((tb, 1, hg, m, dv),
                     lambda i, gi, ci: (i, gi, 0, 0, 0)),
        pl.BlockSpec((tb, 1, hg, m), lambda i, gi, ci: (i, gi, 0, 0)),
    ]
    inputs += [c.astype(jnp.float32), s, z]

    out, s_new, z_new, c_new = pl.pallas_call(
        functools.partial(kernel, stabilize=stabilize, eps=eps),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((tb, 1, hg, t, dv),
                         lambda i, gi, ci: (i, gi, 0, ci, 0)),
            pl.BlockSpec((tb, 1, hg, m, dv),
                         lambda i, gi, ci: (i, gi, 0, 0, 0)),
            pl.BlockSpec((tb, 1, hg, m), lambda i, gi, ci: (i, gi, 0, 0)),
            pl.BlockSpec((tb, 1), lambda i, gi, ci: (i, gi)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, g, hg, lp, dv), v.dtype),
            jax.ShapeDtypeStruct((b, g, hg, m, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, g, hg, m), jnp.float32),
            jax.ShapeDtypeStruct((b, g), jnp.float32),
        ),
        # the state pool (c, s, z) is updated IN PLACE: input n_state is
        # c -> output 3, n_state+1 is s -> output 1, n_state+2 is z -> 2
        input_output_aliases={n_state: 3, n_state + 1: 1, n_state + 2: 2},
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*inputs)
    return out[:, :, :, :l], s_new, z_new, c_new
