"""Pallas TPU kernels for the PRF-attention hot spots (+ jnp oracles).

Kernels (each: <name>.py pallas_call + BlockSpec, oracle in ref.py, jit'd
differentiable wrapper in ops.py):

  * linear_attn_scan  — chunked causal linear attention (the O(Lmd) scan
    that replaces the softmax O(L^2 d) matmuls; paper Fig. 1)
  * prf_featmap       — fused phi(x) = exp(W Mx - ||Mx||^2/2 - c)/sqrt(m)
  * prf_decode_step   — fused one-token serving update of the (S, z)
    prefix state with online-stabilizer rescale (forward-only)
  * prf_fused_decode  — the decode MEGAKERNEL: projection -> exp feature
    map with in-kernel running-max stabilizer -> rank-1 (S, z) update ->
    readout, pool aliased in place (forward-only; subsumes the
    prf_featmap + prf_decode_step pair on the serving hot path)
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (fused_prf_decode, linear_attention_causal,
                               linear_attention_decode_step, prf_featmap)
