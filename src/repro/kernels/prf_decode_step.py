"""Pallas TPU kernel: fused one-token PRF decode step.

The serving hot loop of linear attention (docs/kernels.md §Decode): per
(batch*group*head) row, given the feature-mapped query/key qf, kf (m,),
the value v (dv,), the running prefix state S (m x dv), normalizer z (m)
and the online-stabilizer rescale factor rho = exp(c_old - c_new):

    S' = rho * S + kf v^T          z' = rho * z + kf
    out = (qf . S') / (qf . z' + eps)

fused in VMEM so S never round-trips to HBM between the rescale, the
rank-1 update and the readout. This is the gather/scatter counterpart of
``linear_attn_scan``: that kernel carries (S, z) across sequence chunks
at prefill time; this one advances the same state by exactly one token
for a batch of independent serving slots.

Grid: rows tiled by ``block_b``; each grid step owns ``block_b``
independent slots, so the grid axis is embarrassingly parallel. All
compute is VPU (rank-1 update + row reductions); there is no matmul.
VMEM per step (f32): block_b * (2m + 2dv + 2*m*dv + 1) — for
block_b = 8, m = 256, dv = 128: ~2.1 MB « 16 MB.

On non-TPU backends the wrapper in ``repro.kernels.ops`` runs this with
interpret=True (same numerics, no Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(qf_ref, kf_ref, v_ref, r_ref, s_ref, z_ref,
            o_ref, so_ref, zo_ref, *, eps: float):
    qf = qf_ref[...].astype(jnp.float32)       # (Tb, m)
    kf = kf_ref[...].astype(jnp.float32)       # (Tb, m)
    v = v_ref[...].astype(jnp.float32)         # (Tb, dv)
    rho = r_ref[...].astype(jnp.float32)       # (Tb, 1)
    s = s_ref[...].astype(jnp.float32)         # (Tb, m, dv)
    z = z_ref[...].astype(jnp.float32)         # (Tb, m)

    s_new = s * rho[:, :, None] + kf[:, :, None] * v[:, None, :]
    z_new = z * rho + kf
    num = jnp.sum(qf[:, :, None] * s_new, axis=1)            # (Tb, dv)
    den = jnp.sum(qf * z_new, axis=1, keepdims=True)         # (Tb, 1)

    o_ref[...] = (num / (den + eps)).astype(o_ref.dtype)
    so_ref[...] = s_new.astype(so_ref.dtype)
    zo_ref[...] = z_new.astype(zo_ref.dtype)


def prf_decode_step_fwd(qf: Array, kf: Array, v: Array, s: Array,
                        z: Array, rescale: Array, *, eps: float = 1e-6,
                        block_b: int = 8, interpret: bool = False):
    """qf, kf, z: (N, m); v: (N, dv); s: (N, m, dv); rescale: (N, 1).

    Returns (out (N, dv), s_new (N, m, dv), z_new (N, m)), all f32.
    N is flattened batch*groups*heads; rows are independent slots.
    """
    n, m = qf.shape
    dv = v.shape[-1]
    tb = min(block_b, n)
    pad = (-n) % tb
    if pad:
        padrow = lambda x: jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
        qf, kf, v, s, z, rescale = map(padrow, (qf, kf, v, s, z, rescale))
    npad = n + pad
    grid = (npad // tb,)

    out, s_new, z_new = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, m), lambda i: (i, 0)),
            pl.BlockSpec((tb, m), lambda i: (i, 0)),
            pl.BlockSpec((tb, dv), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
            pl.BlockSpec((tb, m, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, m), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tb, dv), lambda i: (i, 0)),
            pl.BlockSpec((tb, m, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, m), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((npad, dv), jnp.float32),
            jax.ShapeDtypeStruct((npad, m, dv), jnp.float32),
            jax.ShapeDtypeStruct((npad, m), jnp.float32),
        ),
        interpret=interpret,
    )(qf, kf, v, rescale, s, z)
    return out[:n], s_new[:n], z_new[:n]
