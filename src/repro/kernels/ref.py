"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (interpret=True
on CPU; compiled on TPU) and the fallback used in autodiff backward passes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def linear_attention_causal_ref(qf: Array, kf: Array, v: Array,
                                eps: float = 1e-6) -> Array:
    """Causal linear attention, O(L^2) masked form. qf,kf: (N, L, m);
    v: (N, L, dv). N = flattened batch*heads."""
    scores = jnp.einsum("nqm,nkm->nqk", qf.astype(jnp.float32),
                        kf.astype(jnp.float32))
    l = qf.shape[1]
    mask = jnp.tril(jnp.ones((l, l), dtype=bool))
    scores = jnp.where(mask[None], scores, 0.0)
    num = jnp.einsum("nqk,nkd->nqd", scores, v.astype(jnp.float32))
    den = jnp.sum(scores, axis=-1, keepdims=True)
    return (num / (den + eps)).astype(v.dtype)


def prf_featmap_ref(x: Array, m_mat: Array | None, w: Array,
                    c: Array) -> Array:
    """DARKFormer/Performer feature map. x: (N, d); m_mat: (r, d) or None
    (isotropic); w: (m, r); c: scalar stabilizer. Returns (N, m) f32."""
    x = x.astype(jnp.float32)
    if m_mat is not None:
        x = x @ m_mat.astype(jnp.float32).T
    logits = x @ w.astype(jnp.float32).T
    sq = 0.5 * jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    m = w.shape[0]
    return jnp.exp(logits - sq - c) * (m ** -0.5)


def linear_attention_carry_ref(qf: Array, kf: Array, v: Array,
                               s0: Array, z0: Array, eps: float = 1e-6):
    """Causal linear attention resumed from a prefix state — O(L^2) masked
    oracle for the carry kernel. qf, kf: (N, L, m); v: (N, L, dv);
    s0: (N, m, dv); z0: (N, m). Returns (out, s_new, z_new)."""
    f32 = jnp.float32
    qf, kf, v, s0, z0 = (t.astype(f32) for t in (qf, kf, v, s0, z0))
    scores = jnp.einsum("nqm,nkm->nqk", qf, kf)
    l = qf.shape[1]
    mask = jnp.tril(jnp.ones((l, l), dtype=bool))
    scores = jnp.where(mask[None], scores, 0.0)
    num = jnp.einsum("nqm,nmd->nqd", qf, s0) + jnp.einsum(
        "nqk,nkd->nqd", scores, v)
    den = (jnp.einsum("nqm,nm->nq", qf, z0)
           + jnp.sum(scores, axis=-1))[..., None]
    s_new = s0 + jnp.einsum("nlm,nld->nmd", kf, v)
    z_new = z0 + jnp.sum(kf, axis=1)
    return num / (den + eps), s_new, z_new


def prf_decode_step_ref(qf: Array, kf: Array, v: Array, s: Array,
                        z: Array, rescale: Array, eps: float = 1e-6):
    """One-token PRF decode oracle. qf, kf, z: (N, m); v: (N, dv);
    s: (N, m, dv); rescale: (N, 1). Returns (out, s_new, z_new), f32."""
    f32 = jnp.float32
    qf, kf, v, s, z, rescale = (t.astype(f32)
                                for t in (qf, kf, v, s, z, rescale))
    s_new = s * rescale[:, :, None] + kf[:, :, None] * v[:, None, :]
    z_new = z * rescale + kf
    num = jnp.einsum("nm,nmd->nd", qf, s_new)
    den = jnp.einsum("nm,nm->n", qf, z_new)[:, None]
    return num / (den + eps), s_new, z_new


def rglru_ref(x: Array, a: Array, gate: Array, h0: Array) -> tuple[Array,
                                                                   Array]:
    """RG-LRU diagonal recurrence oracle (Griffin, arXiv:2402.19427).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (g_t * x_t)
    x, a, gate: (N, L, d) with a in (0, 1); h0: (N, d).
    Returns (h_all (N, L, d), h_last (N, d)).
    """
    x = x.astype(jnp.float32)
    a = a.astype(jnp.float32)
    inp = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0)) * (
        gate.astype(jnp.float32) * x)

    def step(h, xs):
        a_t, i_t = xs
        h = a_t * h + i_t
        return h, h

    hl, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                          (jnp.moveaxis(a, 1, 0), jnp.moveaxis(inp, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), hl


def wkv6_ref(r: Array, k: Array, v: Array, w: Array, u: Array,
             s0: Array) -> tuple[Array, Array]:
    """RWKV-6 WKV recurrence oracle (arXiv:2404.05892).

    Per head: S_t = diag(w_t) S_{t-1} + k_t v_t^T
              o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    r,k,v,w: (N, L, dh); u: (dh,); s0: (N, dh, dh). w_t in (0,1) decay.
    Returns (o (N, L, dh), s_last).
    """
    def step(s, xs):
        r_t, k_t, v_t, w_t = xs
        kv = k_t[:, :, None] * v_t[:, None, :]
        o = jnp.einsum("nd,nde->ne", r_t, s + u[None, :, None] * kv)
        s = w_t[:, :, None] * s + kv
        return s, o

    args = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                 for t in (r, k, v, w))
    s_last, outs = jax.lax.scan(step, s0.astype(jnp.float32), args)
    return jnp.moveaxis(outs, 0, 1), s_last


def prf_fused_prefill_ref(q: Array, k: Array, v: Array, a: Array,
                          m_mat: Array | None, s: Array, z: Array,
                          c: Array, valid_len: Array | None = None, *,
                          stabilize: bool = True, eps: float = 1e-6):
    """Fused data-aligned PRF prefill-chunk oracle — projection, exp
    feature map with the running-max k-stabilizer (ONE max over the
    whole chunk, the jnp ``_resume_qk_features`` trajectory), ragged
    ``valid_len`` masking, causal carried-state attention and the
    resumable (S, z, c) advance, all from RAW scaled q/k.

    q: (B, G, Hg, L, d); k, v: (B, G, L, d|dv); a: (G, d, m)
    precomposed (W M)^T; m_mat: (G, r, d) or None (isotropic norm);
    s: (B, G, Hg, m, dv); z: (B, G, Hg, m); c: (B, G); valid_len:
    (B,) int32 or None (all rows full). Returns (out (B, G, Hg, L, dv)
    f32, s_new, z_new, c_new), with outputs at masked positions
    garbage by contract.
    """
    f32 = jnp.float32
    q, k, v, a, s, z, c = (t.astype(f32)
                           for t in (q, k, v, a, s, z, c))
    b, g, hg, l, _ = q.shape
    m = a.shape[-1]
    dv = v.shape[-1]
    inv_sqrt_m = m ** -0.5
    neg = jnp.finfo(f32).min

    def raw(x, eq):
        logits = jnp.einsum(eq + ",gdm->" + eq.replace("d", "m"), x, a)
        xt = x if m_mat is None else jnp.einsum(
            eq + ",grd->" + eq.replace("d", "r"), x, m_mat.astype(f32))
        return logits - 0.5 * jnp.sum(xt * xt, -1, keepdims=True)

    qraw = raw(q, "bghld")                               # (B,G,Hg,L,m)
    kraw = raw(k, "bgld")                                # (B,G,L,m)
    if valid_len is None:
        valid = jnp.ones((b, l), bool)
    else:
        valid = jnp.arange(l)[None] < valid_len[:, None]
    kraw_m = jnp.where(valid[:, None, :, None], kraw, neg)
    if stabilize:
        c_new = jnp.maximum(c, jnp.max(kraw_m, axis=(-2, -1)))
        rho = jnp.exp(c - c_new)
        kf = jnp.exp(kraw - c_new[..., None, None]) * inv_sqrt_m
        qraw_m = jnp.where(valid[:, None, None, :, None], qraw, neg)
        qf = jnp.exp(qraw - jnp.max(qraw_m, axis=(-2, -1),
                                    keepdims=True)) * inv_sqrt_m
    else:
        c_new = jnp.zeros_like(c)
        rho = jnp.exp(c)
        kf = jnp.exp(kraw) * inv_sqrt_m
        qf = jnp.exp(qraw) * inv_sqrt_m
    kf = jnp.where(valid[:, None, :, None], kf, 0.0)

    kfb = jnp.broadcast_to(kf[:, :, None], (b, g, hg, l, m))
    vb = jnp.broadcast_to(v[:, :, None], (b, g, hg, l, dv))
    s0 = s * rho[:, :, None, None, None]
    z0 = z * rho[:, :, None, None]
    out, s_new, z_new = linear_attention_carry_ref(
        qf.reshape(-1, l, m), kfb.reshape(-1, l, m),
        vb.reshape(-1, l, dv), s0.reshape(-1, m, dv),
        z0.reshape(-1, m), eps=eps)
    return (out.reshape(b, g, hg, l, dv),
            s_new.reshape(b, g, hg, m, dv),
            z_new.reshape(b, g, hg, m), c_new)


def prf_fused_decode_ref(q: Array, k: Array, v: Array, a: Array,
                         m_mat: Array | None, s: Array, z: Array,
                         c: Array, *, stabilize: bool = True,
                         eps: float = 1e-6):
    """Fused data-aligned PRF decode oracle — projection, exp feature
    map with the online running-max k-stabilizer, rank-1 (S, z) update
    and readout, all from RAW scaled q/k.

    q: (B, G, Hg, d); k, v: (B, G, d|dv); a: (G, d, m) precomposed
    (W M)^T; m_mat: (G, r, d) or None (isotropic norm); s: (B, G, Hg,
    m, dv); z: (B, G, Hg, m); c: (B, G). Returns (out, s_new, z_new,
    c_new), f32.
    """
    f32 = jnp.float32
    q, k, v, a, s, z, c = (t.astype(f32)
                           for t in (q, k, v, a, s, z, c))
    m = a.shape[-1]
    inv_sqrt_m = m ** -0.5

    def raw(x, eq):
        logits = jnp.einsum(eq + ",gdm->" + eq.replace("d", "m"), x, a)
        xt = x if m_mat is None else jnp.einsum(
            eq + ",grd->" + eq.replace("d", "r"), x,
            m_mat.astype(f32))
        return logits - 0.5 * jnp.sum(xt * xt, -1, keepdims=True)

    qraw = raw(q, "bghd")                                # (B, G, Hg, m)
    kraw = raw(k, "bgd")                                 # (B, G, m)
    if stabilize:
        qf = jnp.exp(qraw - jnp.max(qraw, -1, keepdims=True)) * inv_sqrt_m
        c_new = jnp.maximum(c, jnp.max(kraw, -1))
        rho = jnp.exp(c - c_new)
        kf = jnp.exp(kraw - c_new[..., None]) * inv_sqrt_m
    else:
        qf = jnp.exp(qraw) * inv_sqrt_m
        c_new = jnp.zeros_like(c)
        rho = jnp.exp(c)
        kf = jnp.exp(kraw) * inv_sqrt_m
    r4 = rho[:, :, None, None, None]                     # (B,G,1,1,1)
    s_new = s * r4 + kf[:, :, None, :, None] * v[:, :, None, None, :]
    z_new = z * rho[:, :, None, None] + kf[:, :, None, :]
    num = jnp.einsum("bghm,bghmd->bghd", qf, s_new)
    den = jnp.einsum("bghm,bghm->bgh", qf, z_new)[..., None]
    return num / (den + eps), s_new, z_new, c_new
