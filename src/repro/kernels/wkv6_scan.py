"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Grid: (batch*heads) parallel x sequence-chunks sequential; the (dh x dh)
state S is carried in VMEM scratch across chunks. Within a chunk the
recurrence is stepped with an in-register fori_loop — per step the work is
three (dh x dh) VPU element-wise ops + one (1 x dh)(dh x dh) matvec, all
resident in VMEM (dh = 64 for every RWKV-6 size). The data-dependent decay
w_t (the "Finch" feature) rules out the pure-matmul chunk form without
log-space renormalization; the in-VMEM stepped form sidesteps that
stability issue (see ref.wkv6_ref for the oracle).

VMEM per grid step (f32): 4*T*dh (r,k,v,w) + dh^2 (S) + T*dh (o)
  = T=256, dh=64: ~350 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import compiler_params

Array = jax.Array


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, t: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)     # (T, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)   # (1, dh)

    def step(i, carry):
        s, o_acc = carry
        kv = k[i][:, None] * v[i][None, :]              # (dh, dh)
        o_i = (r[i][None, :] @ (s + u.T * kv))[0]       # (dh,)
        s = w[i][:, None] * s + kv
        o_acc = jax.lax.dynamic_update_index_in_dim(o_acc, o_i, i, 0)
        return s, o_acc

    s0 = s_ref[...]
    o0 = jnp.zeros((t, v.shape[1]), jnp.float32)
    s_fin, o = jax.lax.fori_loop(0, t, step, (s0, o0))
    s_ref[...] = s_fin
    o_ref[0] = o.astype(o_ref.dtype)


def wkv6_fwd(r: Array, k: Array, v: Array, w: Array, u: Array, *,
             chunk: int = 256, interpret: bool = False) -> Array:
    """r,k,v,w: (N, L, dh); u: (dh,) -> o: (N, L, dh).

    N = batch*heads flattened; L padded to a chunk multiple (w=1, k=0 in
    the pad keeps the state frozen, so padding is exact).
    """
    n, l, dh = r.shape
    t = min(chunk, l)
    pad = (-l) % t
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)),
                    constant_values=1.0)
    lp = l + pad
    grid = (n, lp // t)
    out = pl.pallas_call(
        functools.partial(_kernel, t=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, t, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, t, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, t, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dh), lambda b, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, dh), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((n, lp, dh), v.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(r, k, v, w, u.reshape(1, dh))
    return out[:, :l]
