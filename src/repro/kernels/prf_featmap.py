"""Pallas TPU kernel: fused DARKFormer PRF feature map.

Computes, without materializing the re-embedding x~ = M x in HBM:

    phi(x) = exp( W (M x) - ||M x||^2 / 2 - c ) / sqrt(m)

i.e. two chained matmuls + row-norm + exp fused in VMEM. For the isotropic
(Performer/LFK) map, M is identity and the wrapper passes m_mat=None to a
single-matmul variant.

Grid: rows of x tiled by ``block_n``; W and M stay resident in VMEM
(m x r and r x d — e.g. 256x128 + 128x128 f32 = 192 KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel_dark(x_ref, m_ref, w_ref, c_ref, o_ref, *, m_feats: int):
    x = x_ref[...].astype(jnp.float32)           # (Tn, d)
    m_mat = m_ref[...].astype(jnp.float32)       # (r, d)
    w = w_ref[...].astype(jnp.float32)           # (m, r)
    c = c_ref[0, 0]
    xt = jax.lax.dot_general(x, m_mat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Tn, r)
    logits = jax.lax.dot_general(xt, w, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    sq = 0.5 * jnp.sum(xt * xt, axis=1, keepdims=True)
    o_ref[...] = (jnp.exp(logits - sq - c)
                  * (m_feats ** -0.5)).astype(o_ref.dtype)


def _kernel_iso(x_ref, w_ref, c_ref, o_ref, *, m_feats: int):
    x = x_ref[...].astype(jnp.float32)           # (Tn, d)
    w = w_ref[...].astype(jnp.float32)           # (m, d)
    c = c_ref[0, 0]
    logits = jax.lax.dot_general(x, w, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    sq = 0.5 * jnp.sum(x * x, axis=1, keepdims=True)
    o_ref[...] = (jnp.exp(logits - sq - c)
                  * (m_feats ** -0.5)).astype(o_ref.dtype)


def prf_featmap_fwd(x: Array, m_mat: Array | None, w: Array, c: Array, *,
                    block_n: int = 256, interpret: bool = False) -> Array:
    """x: (N, d); m_mat: (r, d) | None; w: (m, r); c: scalar. -> (N, m) f32."""
    n, d = x.shape
    m_feats = w.shape[0]
    t = min(block_n, n)
    pad = (-n) % t
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    npad = n + pad
    grid = (npad // t,)
    c_arr = jnp.asarray(c, jnp.float32).reshape(1, 1)
    if m_mat is not None:
        r = m_mat.shape[0]
        out = pl.pallas_call(
            functools.partial(_kernel_dark, m_feats=m_feats),
            grid=grid,
            in_specs=[
                pl.BlockSpec((t, d), lambda i: (i, 0)),
                pl.BlockSpec((r, d), lambda i: (0, 0)),
                pl.BlockSpec((m_feats, r), lambda i: (0, 0)),
                pl.BlockSpec(memory_space=pltpu.SMEM),
            ],
            out_specs=pl.BlockSpec((t, m_feats), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((npad, m_feats), jnp.float32),
            interpret=interpret,
        )(x, m_mat, w, c_arr)
    else:
        out = pl.pallas_call(
            functools.partial(_kernel_iso, m_feats=m_feats),
            grid=grid,
            in_specs=[
                pl.BlockSpec((t, d), lambda i: (i, 0)),
                pl.BlockSpec((m_feats, w.shape[1]), lambda i: (0, 0)),
                pl.BlockSpec(memory_space=pltpu.SMEM),
            ],
            out_specs=pl.BlockSpec((t, m_feats), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((npad, m_feats), jnp.float32),
            interpret=interpret,
        )(x, w, c_arr)
    return out[:n]
