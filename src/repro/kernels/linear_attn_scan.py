"""Pallas TPU kernel: chunked causal linear attention (prefix-state scan).

The compute hot spot of random-feature attention (paper Fig. 1): given
feature-mapped queries/keys Q', K' (L x m) and values V (L x dv), compute

    out_i = ( Q'_i . sum_{j<=i} K'_j V_j^T ) / ( Q'_i . sum_{j<=i} K'_j )

in O(L m dv) by carrying the running state S (m x dv) and normalizer z (m)
across sequence chunks.

TPU adaptation (vs the CUDA shared-memory loop): the (batch*heads) axis maps
to the PARALLEL grid dimension; the chunk axis maps to the LAST (sequential)
grid dimension, so S and z live in VMEM scratch and persist across grid
steps. Within a chunk the causal part is tril(Q'K'^T) V — an MXU-friendly
(T x m)(m x T)(T x dv) matmul chain. T, m, dv should be multiples of the
128-lane register tile for full MXU utilization; the wrapper pads.

VMEM working set per grid step (f32):
    q,k: 2*T*m    v,o: 2*T*dv    S: m*dv    z: m    local: T*T
For T = m = 256, dv = 128: ~1.0 MB « 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import compiler_params

Array = jax.Array


def _kernel(q_ref, k_ref, v_ref, o_ref, s_ref, z_ref, *, eps: float,
            nc: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    q = q_ref[0].astype(jnp.float32)        # (T, m)
    k = k_ref[0].astype(jnp.float32)        # (T, m)
    v = v_ref[0].astype(jnp.float32)        # (T, dv)
    t = q.shape[0]

    s_in = s_ref[...]                        # (m, dv)
    z_in = z_ref[0]                          # (m,)

    local = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (T, T)
    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    local = jnp.where(row >= col, local, 0.0)

    num = (jnp.dot(q, s_in, preferred_element_type=jnp.float32)
           + jnp.dot(local, v, preferred_element_type=jnp.float32))
    den = (jnp.dot(q, z_in[:, None],
                   preferred_element_type=jnp.float32)[:, 0]
           + jnp.sum(local, axis=1))
    o_ref[0] = (num / (den[:, None] + eps)).astype(o_ref.dtype)

    s_ref[...] = s_in + jax.lax.dot_general(
        k, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # K^T V: (m, dv)
    z_ref[0] = z_in + jnp.sum(k, axis=0)


def linear_attention_causal_fwd(qf: Array, kf: Array, v: Array, *,
                                chunk: int = 256, eps: float = 1e-6,
                                interpret: bool = False) -> Array:
    """qf, kf: (N, L, m); v: (N, L, dv) -> (N, L, dv).

    N is flattened batch*heads. L is padded to a multiple of ``chunk``.
    """
    n, l, m = qf.shape
    dv = v.shape[-1]
    t = min(chunk, l)
    pad = (-l) % t
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // t

    grid = (n, nc)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, t, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, t, dv), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((n, lp, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((m, dv), jnp.float32),
            pltpu.VMEM((1, m), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(qf, kf, v)
    return out[:, :l]


def _kernel_carry(q_ref, k_ref, v_ref, s0_ref, z0_ref,
                  o_ref, so_ref, zo_ref, s_ref, z_ref, *, eps: float):
    """Same scan as ``_kernel`` but seeded from (and emitting) the prefix
    state — the chunked-prefill resume point of docs/serving.md."""
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(jnp.float32)
        z_ref[...] = z0_ref[...].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)        # (T, m)
    k = k_ref[0].astype(jnp.float32)        # (T, m)
    v = v_ref[0].astype(jnp.float32)        # (T, dv)
    t = q.shape[0]

    s_in = s_ref[...]                        # (m, dv)
    z_in = z_ref[0]                          # (m,)

    local = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (T, T)
    row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    local = jnp.where(row >= col, local, 0.0)

    num = (jnp.dot(q, s_in, preferred_element_type=jnp.float32)
           + jnp.dot(local, v, preferred_element_type=jnp.float32))
    den = (jnp.dot(q, z_in[:, None],
                   preferred_element_type=jnp.float32)[:, 0]
           + jnp.sum(local, axis=1))
    o_ref[0] = (num / (den[:, None] + eps)).astype(o_ref.dtype)

    s_new = s_in + jax.lax.dot_general(
        k, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # K^T V: (m, dv)
    z_new = z_in + jnp.sum(k, axis=0)
    s_ref[...] = s_new
    z_ref[0] = z_new
    # the state output block is revisited every sequential step; the last
    # chunk's write is what lands in HBM
    so_ref[0] = s_new
    zo_ref[...] = z_new[None]


def linear_attention_causal_carry_fwd(qf: Array, kf: Array, v: Array,
                                      s0: Array, z0: Array, *,
                                      chunk: int = 256, eps: float = 1e-6,
                                      interpret: bool = False
                                      ) -> tuple[Array, Array, Array]:
    """Chunked causal linear attention resumed from a carried prefix state.

    qf, kf: (N, L, m); v: (N, L, dv); s0: (N, m, dv); z0: (N, m).
    Returns (out (N, L, dv) in v.dtype, s (N, m, dv) f32, z (N, m) f32).
    L is padded to a multiple of ``chunk``; padded key rows must be (and
    are, per the wrapper contract) zero features so the final state is
    unaffected.
    """
    n, l, m = qf.shape
    dv = v.shape[-1]
    t = min(chunk, l)
    pad = (-l) % t
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // t

    grid = (n, nc)
    out, s_f, z_f = pl.pallas_call(
        functools.partial(_kernel_carry, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, t, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, t, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, m, dv), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, m), lambda b, c: (b, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, t, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, m, dv), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, m), lambda b, c: (b, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n, lp, dv), v.dtype),
            jax.ShapeDtypeStruct((n, m, dv), jnp.float32),
            jax.ShapeDtypeStruct((n, m), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((m, dv), jnp.float32),
            pltpu.VMEM((1, m), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(qf, kf, v, s0, z0)
    return out[:, :l], s_f, z_f
