"""Version-compat shims shared by every Pallas kernel in this package.

jax renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams`` around
0.5; resolving the class here (once) lets the kernels run on either side
of the rename without each module carrying its own copy of the getattr
dance.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

COMPILER_PARAMS_CLS = getattr(pltpu, 'CompilerParams', None) or \
    pltpu.TPUCompilerParams


def compiler_params(*, dimension_semantics: tuple) -> object:
    """Build TPU compiler params under whichever class this jax exposes."""
    return COMPILER_PARAMS_CLS(dimension_semantics=dimension_semantics)
