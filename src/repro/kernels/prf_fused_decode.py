"""Pallas TPU megakernel: fused data-aligned PRF decode step.

One kernel per (slot-block, KV-group) grid step that takes RAW scaled
q/k/v (d-dim, the 1/sqrt(d) temperature pre-absorbed), the precomposed
data-aligned projection ``A = (W M)^T`` (plain ``W^T`` for the isotropic
Performer/LFK kinds), the carried running k-stabilizer ``c`` and the
(S, z) slot-pool block, and fuses the whole decode hot path in VMEM:

    qraw = q A − ‖Mq‖²/2          kraw = k A − ‖Mk‖²/2
    c'   = max(c, max_m kraw)     ρ = exp(c − c')        (in-kernel
    qf   = exp(qraw − max_m qraw)/√m                      online-max
    kf   = exp(kraw − c')/√m                              stabilizer)
    S'   = ρ S + kf vᵀ            z' = ρ z + kf
    out  = (qf · S') / (qf · z' + ε)

replacing the jnp ``_resume_qk_features`` + two-dispatch
(``prf_featmap`` → ``prf_decode_step``) decode path: the (N, m) feature
tensors never exist in HBM, and ``input_output_aliases`` updates the
S/z/c slot pool IN PLACE instead of allocating a fresh pool-sized
buffer every token — the two HBM round trips that dominate the
memory-bound decode regime (docs/kernels.md §Fused decode).

GQA: k/v are per KV group ((B, G, d)); k-features are computed ONCE per
group inside the kernel and broadcast to the Hg query heads at the
update, instead of materializing (B, G, Hg, m) broadcast features like
the two-kernel path.

Grid: (slot blocks, G); both axes embarrassingly parallel. Slot blocks
never pad: the wrapper shrinks ``block_b`` to a divisor of B so the
aliased pool blocks tile exactly (padding would allocate the pool copy
the aliasing exists to avoid). VMEM per step (f32) is dominated by the
S block: ``block_b·Hg·m·dv`` — for block_b = 8, Hg = 8, m = 256,
dv = 128: ~8 MB of 16 MB; shrink ``block_b`` for bigger geometries.

On non-TPU backends the wrapper in ``repro.kernels.ops`` runs this with
interpret=True (same numerics, no Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import compiler_params

Array = jax.Array


def _featurize(x2, a, m_mat):
    """Raw PRF logits for flattened rows x2 (R, d): x2 A − ‖M x2‖²/2.

    The projection runs through the precomposed A (ONE matmul); the
    norm term needs the low-rank re-embedding M x2 (darkformer) or x2
    itself (isotropic, m_mat None).
    """
    logits = jax.lax.dot_general(x2, a, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    xt = x2 if m_mat is None else jax.lax.dot_general(
        x2, m_mat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    return logits - 0.5 * jnp.sum(xt * xt, axis=-1, keepdims=True)


def _kernel(q_ref, k_ref, v_ref, a_ref, m_ref, c_ref, s_ref, z_ref,
            o_ref, so_ref, zo_ref, co_ref, *, stabilize: bool,
            eps: float):
    tb, _, hg, d = q_ref.shape
    m = a_ref.shape[-1]
    dv = v_ref.shape[-1]
    inv_sqrt_m = m ** -0.5

    q = q_ref[...].astype(jnp.float32).reshape(tb * hg, d)
    k = k_ref[...].astype(jnp.float32).reshape(tb, d)
    v = v_ref[...].astype(jnp.float32).reshape(tb, dv)
    a = a_ref[0].astype(jnp.float32)                     # (d, m)
    m_mat = None if m_ref is None else m_ref[0].astype(jnp.float32)
    c_old = c_ref[...].astype(jnp.float32)               # (Tb, 1)
    s = s_ref[...].astype(jnp.float32).reshape(tb * hg, m, dv)
    z = z_ref[...].astype(jnp.float32).reshape(tb * hg, m)

    qraw = _featurize(q, a, m_mat)                       # (Tb*Hg, m)
    kraw = _featurize(k, a, m_mat)                       # (Tb, m) — ONCE
    #                                                      per KV group
    if stabilize:
        # online running-max: fold the new key's max into the carried
        # stabilizer and rescale the accumulated state ONCE (§3 of
        # docs/kernels.md); the q shift cancels pointwise so the
        # current token's own max is enough.
        qf = jnp.exp(qraw - jnp.max(qraw, axis=-1, keepdims=True)) \
            * inv_sqrt_m
        c_new = jnp.maximum(c_old, jnp.max(kraw, axis=-1, keepdims=True))
        rho = jnp.exp(c_old - c_new)                     # <= 1
        kf = jnp.exp(kraw - c_new) * inv_sqrt_m
    else:
        # unstabilized features carry c == 0 (the init state's -1e30
        # sentinel only ever zeroes an all-zero fresh state)
        qf = jnp.exp(qraw) * inv_sqrt_m
        c_new = jnp.zeros_like(c_old)
        rho = jnp.exp(c_old)
        kf = jnp.exp(kraw) * inv_sqrt_m

    # broadcast per-group kf/v/rho to the Hg query heads of the block
    rho_h = jnp.broadcast_to(rho[:, None], (tb, hg, 1)).reshape(-1, 1)
    kf_h = jnp.broadcast_to(kf[:, None, :], (tb, hg, m)).reshape(-1, m)
    v_h = jnp.broadcast_to(v[:, None, :], (tb, hg, dv)).reshape(-1, dv)

    s_new = s * rho_h[:, :, None] + kf_h[:, :, None] * v_h[:, None, :]
    z_new = z * rho_h + kf_h
    num = jnp.sum(qf[:, :, None] * s_new, axis=1)        # (Tb*Hg, dv)
    den = jnp.sum(qf * z_new, axis=1, keepdims=True)     # (Tb*Hg, 1)

    o_ref[...] = (num / (den + eps)).astype(o_ref.dtype) \
        .reshape(tb, 1, hg, dv)
    so_ref[...] = s_new.astype(so_ref.dtype).reshape(s_ref.shape)
    zo_ref[...] = z_new.astype(zo_ref.dtype).reshape(z_ref.shape)
    co_ref[...] = c_new.astype(co_ref.dtype)


def _block_divisor(b: int, block_b: int) -> int:
    """Largest tile <= block_b that divides b exactly — the aliased pool
    blocks must tile the slot axis with NO padding (a padded copy would
    be exactly the pool-sized allocation the aliasing removes)."""
    tb = max(1, min(block_b, b))
    while b % tb:
        tb -= 1
    return tb


def prf_fused_decode_fwd(q: Array, k: Array, v: Array, a: Array,
                         m_mat: Array | None, s: Array, z: Array,
                         c: Array, *, stabilize: bool = True,
                         eps: float = 1e-6, block_b: int = 8,
                         interpret: bool = False):
    """Advance a (B, G)-slot pool by one token, fully fused.

    q: (B, G, Hg, d); k, v: (B, G, d|dv); a: (G, d, m);
    m_mat: (G, r, d) or None (isotropic); s: (B, G, Hg, m, dv) f32;
    z: (B, G, Hg, m) f32; c: (B, G) f32 running k-stabilizer.

    Returns (out (B, G, Hg, dv) f32, s_new, z_new, c_new) with the
    state outputs ALIASED to the input buffers (in-place pool update
    under jit when the caller donates the pool).
    """
    b, g, hg, d = q.shape
    m = a.shape[-1]
    dv = v.shape[-1]
    tb = _block_divisor(b, block_b)
    grid = (b // tb, g)

    in_specs = [
        pl.BlockSpec((tb, 1, hg, d), lambda i, gi: (i, gi, 0, 0)),
        pl.BlockSpec((tb, 1, d), lambda i, gi: (i, gi, 0)),
        pl.BlockSpec((tb, 1, dv), lambda i, gi: (i, gi, 0)),
        pl.BlockSpec((1, d, m), lambda i, gi: (gi, 0, 0)),
    ]
    inputs = [q, k, v, a]
    if m_mat is not None:
        r = m_mat.shape[-2]
        in_specs.append(pl.BlockSpec((1, r, d), lambda i, gi: (gi, 0, 0)))
        inputs.append(m_mat)
        kernel = _kernel
    else:
        kernel = functools.partial(_no_mmat_kernel, _kernel)
    n_lead = len(inputs)
    in_specs += [
        pl.BlockSpec((tb, 1), lambda i, gi: (i, gi)),
        pl.BlockSpec((tb, 1, hg, m, dv), lambda i, gi: (i, gi, 0, 0, 0)),
        pl.BlockSpec((tb, 1, hg, m), lambda i, gi: (i, gi, 0, 0)),
    ]
    inputs += [c.astype(jnp.float32), s, z]

    out, s_new, z_new, c_new = pl.pallas_call(
        functools.partial(kernel, stabilize=stabilize, eps=eps),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((tb, 1, hg, dv), lambda i, gi: (i, gi, 0, 0)),
            pl.BlockSpec((tb, 1, hg, m, dv),
                         lambda i, gi: (i, gi, 0, 0, 0)),
            pl.BlockSpec((tb, 1, hg, m), lambda i, gi: (i, gi, 0, 0)),
            pl.BlockSpec((tb, 1), lambda i, gi: (i, gi)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, g, hg, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, g, hg, m, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, g, hg, m), jnp.float32),
            jax.ShapeDtypeStruct((b, g), jnp.float32),
        ),
        # the slot pool (s, z, c) is updated IN PLACE: input n_lead is
        # c -> output 3, n_lead+1 is s -> output 1, n_lead+2 is z -> 2
        input_output_aliases={n_lead: 3, n_lead + 1: 1, n_lead + 2: 2},
        interpret=interpret,
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")),
    )(*inputs)
    return out, s_new, z_new, c_new


def _no_mmat_kernel(kernel, q_ref, k_ref, v_ref, a_ref, c_ref, s_ref,
                    z_ref, *out_refs, **kw):
    """Isotropic variant: no m_mat operand; the norm uses x itself."""
    kernel(q_ref, k_ref, v_ref, a_ref, None, c_ref, s_ref, z_ref,
           *out_refs, **kw)
