"""Mesh builders. Functions, not module constants — importing this module
never touches jax device state (required for the dry-run's
xla_force_host_platform_device_count to win the init race)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The deployment mesh: one v5e pod 16x16 (data, model), or two pods
    2x16x16 (pod, data, model). 'pod' is the DCN axis.

    When more placeholder devices exist than the mesh needs (the dry-run
    allocates 512 host devices for both meshes), the first prod(shape) are
    used."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) > n:
        import numpy as np
        return jax.sharding.Mesh(
            np.asarray(devs[:n]).reshape(shape), axes)
    raise ValueError(
        f"need {n} devices for mesh {dict(zip(axes, shape))}, have "
        f"{len(devs)} — run under dryrun.py (it sets "
        f"xla_force_host_platform_device_count)")


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_mesh_for_shape(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary topology (elastic-restart path uses this after a shrink)."""
    return jax.make_mesh(shape, axes)
