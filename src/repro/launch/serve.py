"""Serving launcher: thin CLI over the continuous-batching engine.

Demonstrates the paper's O(1)-state decoding at the system level: with a
PRF kernel the per-sequence serving state is (m x d_v) per head
regardless of context length, and ``repro.serving.ServingEngine``
multiplexes many sequences of different lengths over one batched decode
step — admitting and evicting mid-decode. Compare ``--kernel exact``
(per-slot KV-cache pages) vs ``--kernel darkformer`` (O(1) PRF state).
Design doc: docs/serving.md.

Examples:
  # 8 heterogeneous requests over 4 slots, greedy
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 8 --slots 4 --prompt-len 16-64 --gen 32

  # Poisson open-loop traffic at 2 req/s
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 16 --slots 4 --rate 2.0

  # prefix-heavy traffic: fork the shared 96-token prompt from the cache
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 16 --chunk-tokens 32 --prefix-cache --shared-prefix 96
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro import configs as cfgs
from repro.models import lm
from repro.parallel import param_specs, make_shardings
from repro.serving import PrefixCacheConfig, ServingEngine
from repro.serving.request import shared_prefix_requests, \
    synthetic_requests
from repro import checkpoint as ckpt_lib
from repro.launch import mesh as mesh_lib


def _parse_range(spec: str) -> tuple[int, int]:
    """'64' -> (64, 64); '16-64' -> (16, 64)."""
    if "-" in spec:
        lo, hi = spec.split("-", 1)
        return int(lo), int(hi)
    return int(spec), int(spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--kernel", default=None,
                    help="exact|performer|darkformer|lfk (default: config)")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (max concurrent sequences)")
    ap.add_argument("--max-len", type=int, default=256,
                    help="per-slot context budget (prompt + generated)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", default="16-64",
                    help="prompt length or lo-hi range")
    ap.add_argument("--gen", default="32", help="new tokens or lo-hi range")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = all at t=0")
    ap.add_argument("--realtime", action="store_true",
                    help="sleep through arrival gaps instead of skipping")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked prefill: spend at most N prompt tokens "
                         "per engine step so admissions interleave with "
                         "decode (default: blocking whole-prompt prefill)")
    ap.add_argument("--prefill-rows", type=int, default=None,
                    help="cap on staged admissions sharing one batched "
                         "prefill call (default: all staged; 1 = serial "
                         "one-admission-per-step schedule)")
    ap.add_argument("--no-bucket-prefill", action="store_true",
                    help="disable pow-2 bucketing of packed prefill chunk "
                         "lengths (more recompiles, zero padding waste)")
    ap.add_argument("--overlap", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="pipelined step loop: concurrent prefill/decode "
                         "dispatch, double-buffered chunk packing, "
                         "one-step-delayed non-blocking token readback "
                         "(--no-overlap = sequential reference scheduler; "
                         "token streams are identical either way)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route prefill/decode through the Pallas kernels "
                         "(decode = the fused prf_fused_decode megakernel "
                         "with engine-precomposed projections); PRF kinds "
                         "only — warns and is ignored for --kernel exact, "
                         "whose softmax decode has no Pallas path")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="capture prefix snapshots at block boundaries "
                         "and admit later requests sharing a cached "
                         "prefix by forking its state (O(1) for PRF "
                         "kinds; exact switches to paged KV with "
                         "copy-on-write page sharing)")
    ap.add_argument("--prefix-block", type=int, default=16,
                    help="prefix-cache capture/match granularity in "
                         "tokens (align with --chunk-tokens grants)")
    ap.add_argument("--prefix-device-mb", type=int, default=64,
                    help="device-tier snapshot budget (MiB) before LRU "
                         "demotion to host")
    ap.add_argument("--prefix-host-mb", type=int, default=256,
                    help="host-tier snapshot budget (MiB) before LRU "
                         "eviction")
    ap.add_argument("--page-size", type=int, default=16,
                    help="exact paged-KV page size in tokens "
                         "(prefix-cache engines only)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="generate prefix-heavy traffic: N-token shared "
                         "prompt prefix on ~80%% of requests (0 = fully "
                         "random prompts)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k sampling (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="per-request nucleus sampling (1.0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--load", default=None, help="checkpoint dir")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args()

    cfg = cfgs.get_config(args.arch, reduced=args.reduced)
    if args.kernel:
        # of FEATURE_KINDS, only these have a prefill/decode state path
        # (trig/random/constant are training-time baselines)
        servable = ("exact", "performer", "darkformer", "lfk")
        if args.kernel not in servable:
            raise SystemExit(f"unservable --kernel {args.kernel!r} "
                             f"(choose from {', '.join(servable)})")
        cfg = cfgs.darkify(cfg, args.kernel, cfg.attn.num_features)
    if args.use_kernel:
        if cfg.attn.kind == "exact":
            # previously accepted silently while doing nothing — the
            # exact softmax decode has no Pallas path to select
            print("warning: --use-kernel has no effect with the 'exact' "
                  "kernel (Pallas paths exist for the PRF kinds only); "
                  "ignoring the flag", file=sys.stderr)
        else:
            import dataclasses
            cfg = dataclasses.replace(cfg, use_kernel=True)
    if cfg.modality != "text":
        raise SystemExit("serving engine drives text decode only")
    mesh = mesh_lib.make_local_mesh(args.mesh_data, args.mesh_model)

    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.load:
        params, _ = ckpt_lib.restore_checkpoint(args.load, params)
    pshard = make_shardings(
        param_specs(params, mesh, moe=cfg.moe is not None), mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, pshard)

    # a non-trivial mesh shards the slot + staging pools too (pools are
    # device_put per serve_state_specs and constrained inside the jitted
    # steps); a 1x1 mesh keeps the single-device fast path
    pool_mesh = mesh if args.mesh_data * args.mesh_model > 1 else None
    pc = None
    if args.prefix_cache:
        pc = PrefixCacheConfig(block_tokens=args.prefix_block,
                               device_bytes=args.prefix_device_mb << 20,
                               host_bytes=args.prefix_host_mb << 20,
                               page_size=args.page_size)
    engine = ServingEngine(params, cfg, max_slots=args.slots,
                           max_len=args.max_len,
                           chunk_tokens=args.chunk_tokens,
                           seed=args.seed, mesh=pool_mesh,
                           prefill_rows=args.prefill_rows,
                           bucket_prefill=not args.no_bucket_prefill,
                           overlap=args.overlap, prefix_cache=pc)
    if args.shared_prefix > 0:
        reqs = shared_prefix_requests(
            args.requests, cfg.vocab, seed=args.seed, rate=args.rate,
            prefix_len=args.shared_prefix,
            suffix_range=_parse_range(args.prompt_len),
            gen_range=_parse_range(args.gen),
            temperature=args.temperature)
    else:
        reqs = synthetic_requests(
            args.requests, cfg.vocab, seed=args.seed, rate=args.rate,
            prompt_range=_parse_range(args.prompt_len),
            gen_range=_parse_range(args.gen),
            temperature=args.temperature,
            top_k=args.top_k, top_p=args.top_p)
    try:
        for r in reqs:
            engine.submit(r)
    except ValueError as e:                    # e.g. prompt >= max_len
        raise SystemExit(f"bad request: {e}")

    print(f"serving {args.requests} requests over {args.slots} slots "
          f"(kernel={cfg.attn.kind}, max_len={args.max_len}, "
          f"rate={args.rate or 'batch'}"
          + (f", mesh={args.mesh_data}x{args.mesh_model}" if pool_mesh
             is not None else "") + ")")
    results = engine.run(realtime=args.realtime)

    for res in sorted(results, key=lambda r: r.uid):
        span = res.finish_time - res.arrival_time
        print(f"  req {res.uid}: prompt={len(res.prompt)} "
              f"gen={len(res.tokens)} ttft={res.ttft * 1e3:.0f}ms "
              f"span={span:.2f}s tokens[:8]={res.tokens[:8]}")

    st = engine.stats
    print(f"attention paths: prefill={st['prefill_path']} "
          f"decode={st['decode_path']} "
          f"scheduler={'overlap' if st['overlap'] else 'sequential'}")
    if "decode_stall_ms_p50" in st:
        print(f"decode stall (host blocked on token readiness): "
              f"p50={st['decode_stall_ms_p50']:.2f}ms "
              f"p99={st['decode_stall_ms_p99']:.2f}ms "
              f"max={st['decode_stall_ms_max']:.2f}ms; "
              f"dispatch depth mean={st['dispatch_depth_mean']:.1f} "
              f"max={st['dispatch_depth_max']}")
    tpots = np.array([t for r in results for t in r.tpots])
    span = max(r.finish_time for r in results) - min(
        r.arrival_time for r in results)
    print(f"throughput: {st['emitted_tokens'] / max(span, 1e-9):.1f} tok/s "
          f"({st['emitted_tokens']} tokens in {span:.2f}s)")
    if tpots.size:
        print(f"per-token latency: p50={np.percentile(tpots, 50) * 1e3:.1f}ms "
              f"p99={np.percentile(tpots, 99) * 1e3:.1f}ms")
    if "ttft_p50" in st:
        print(f"ttft: p50={st['ttft_p50'] * 1e3:.0f}ms "
              f"p99={st['ttft_p99'] * 1e3:.0f}ms")
    if "prefix_hits" in st:
        line = (f"prefix cache: hit rate "
                f"{st['prefix_hit_rate'] * 100:.0f}% "
                f"({st['prefix_hits']}/{st['prefix_hits'] + st['prefix_misses']}), "
                f"{st['forked_tokens']} prompt tokens forked over "
                f"{st['forked_requests']} requests; "
                f"{st['prefix_entries']} entries "
                f"({st['prefix_device_bytes'] >> 10}KiB dev / "
                f"{st['prefix_host_bytes'] >> 10}KiB host), "
                f"{st['prefix_evictions']} evictions")
        if st.get("paged_kv"):
            line += (f"; paged KV: {st['kv_pages_total']} pages x "
                     f"{st['kv_page_size']} tok, "
                     f"{st['kv_pages_free']} free")
        print(line)
    print(f"slot occupancy: {st['mean_occupancy'] * 100:.0f}% over "
          f"{st['decode_steps']} decode steps")
    print(f"prefill: {st['prefill_tokens']} tokens in "
          f"{st['prefill_chunks']} chunks over {st['prefill_calls']} "
          f"batched calls ({st['prefill_rows_per_call']:.1f} rows/call, "
          f"batch occupancy {st['prefill_batch_occupancy'] * 100:.0f}%, "
          f"max {st['max_prefill_tokens_per_step']} tokens per step)")


if __name__ == "__main__":
    main()
