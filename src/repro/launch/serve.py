"""Serving launcher: batched prefill + decode loop (deliverable (b)).

Demonstrates the paper's O(1)-state decoding: with a PRF kernel the serving
state is (m x d_v) per head regardless of context length, so 32k- and
500k-context decode cost the same. Compare --kernel exact (KV cache) vs
--kernel darkformer.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --prompt-len 64 --gen 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.parallel import param_specs, make_shardings
from repro import checkpoint as ckpt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--kernel", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--load", default=None, help="checkpoint dir")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args()

    cfg = cfgs.get_config(args.arch, reduced=args.reduced)
    if args.kernel:
        cfg = cfgs.darkify(cfg, args.kernel, cfg.attn.num_features)
    if cfg.modality == "audio":
        raise SystemExit("encoder-only arch has no decode path")
    mesh = mesh_lib.make_local_mesh(args.mesh_data, args.mesh_model)
    max_len = args.max_len or (args.prompt_len + args.gen + 8)

    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.load:
        params, _ = ckpt_lib.restore_checkpoint(args.load, params)
    pshard = make_shardings(
        param_specs(params, mesh, moe=cfg.moe is not None), mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, pshard)

    key = jax.random.PRNGKey(args.seed + 1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
    batch = {"tokens": prompt}
    if cfg.modality == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.num_patches, cfg.d_model), cfg.param_dtype)

    prefill_fn = jax.jit(steps_lib.make_prefill_step(cfg, max_len))
    decode_fn = jax.jit(steps_lib.make_decode_step(cfg),
                        donate_argnums=(2,))

    t0 = time.time()
    logits, state = prefill_fn(params, batch)
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill:.3f}s "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

    tok = jnp.argmax(logits[:, -1], axis=-1)
    outs = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, state = decode_fn(params, tok, state)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub,
                                         logits / args.temperature, -1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        outs.append(tok)
    jax.block_until_ready(outs[-1])
    t_dec = time.time() - t0
    gen = jnp.stack(outs, axis=1)
    print(f"decode: {args.batch}x{args.gen - 1} tokens in {t_dec:.3f}s "
          f"({args.batch * (args.gen - 1) / t_dec:.0f} tok/s)")
    print("sample[0]:", gen[0].tolist())


if __name__ == "__main__":
    main()
