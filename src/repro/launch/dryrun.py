import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax-importing import: jax locks the device count on
# first backend init. Only the dry-run gets 512 placeholder devices.

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import functools       # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs as cfgs                      # noqa: E402
from repro.configs.base import SHAPES                  # noqa: E402
from repro.launch import mesh as mesh_lib              # noqa: E402
from repro.launch import steps as steps_lib            # noqa: E402
from repro.models import lm                            # noqa: E402
from repro.optim import AdamWConfig, adamw_init        # noqa: E402
from repro.optim.schedules import cosine_warmup        # noqa: E402
from repro.parallel import (param_specs, opt_state_specs, batch_specs,
                            serve_state_specs, make_shardings)  # noqa: E402

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * every input is a ShapeDtypeStruct (no allocation),
  * .lower().compile() must succeed on the 16x16 single-pod mesh AND the
    2x16x16 multi-pod mesh,
  * memory_analysis / cost_analysis / the collective schedule parsed from
    the optimized HLO feed EXPERIMENTS.md §Dry-run and §Roofline.

Results are cached as JSON per cell under experiments/dryrun/.
"""

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,4096,512]{...}' -> bytes. Tuples handled by the caller."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in optimized HLO.

    Returns {op_kind: bytes, "total": bytes, "count": n}. Per-device
    payload approximated by the op's result shape (received bytes).
    """
    out = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*) ([a-z\-]+)", ls)
        if not m:
            continue
        shape_s, op = m.groups()
        if op not in _COLLECTIVES:
            continue
        if op == "all-to-all" and "-start" in ls:
            pass
        count += 1
        if shape_s.startswith("("):
            inner = re.findall(r"[a-z0-9]+\[[0-9,]*\]", shape_s)
            b = sum(_shape_bytes(s) for s in inner)
        else:
            b = _shape_bytes(shape_s)
        out[op] += b
    # async pairs (xxx-start / xxx-done) show the payload on -start only;
    # the regex above already counts each op name once per line.
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["count"] = count
    return out


def hbm_traffic_bytes(cost: dict) -> float:
    return float(cost.get("bytes accessed", 0.0))


def build_cell(arch: str, shape_name: str, mesh, kernel: str,
               remat: str = "dots", cfg=None, preset: str = "2d",
               shard_features: bool = False, pin_moe: bool = False):
    """Returns (lower_fn, meta) for the cell; lower_fn() -> jax.Lowered."""
    if cfg is None:
        cfg = cfgs.get_config(arch)
        if kernel:
            cfg = cfgs.darkify(cfg, kernel, cfg.attn.num_features)
        cfg = dataclasses.replace(cfg, remat=remat)
    if pin_moe and cfg.moe is not None:
        from repro.parallel.sharding import dp_axes
        eax = ("model" if cfg.moe.num_experts % mesh.shape["model"] == 0
               else None)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch_spec=(dp_axes(mesh), eax)))
    ok, why = cfgs.cell_supported(cfg, shape_name)
    if not ok:
        return None, {"skipped": why}
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    batch = cfgs.input_specs(cfg, shape_name)
    pshape = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    pspecs = param_specs(pshape, mesh, moe=cfg.moe is not None,
                         preset=preset, shard_features=shard_features,
                         overrides=cfg.sharding_overrides)
    pshard = make_shardings(pspecs, mesh)
    bshard = make_shardings(batch_specs(batch, mesh, preset=preset), mesh)
    meta = {"arch": arch, "shape": shape_name, "kind": kind,
            "kernel": cfg.attn.kind,
            "mesh": dict(zip(mesh.axis_names,
                             [mesh.shape[a] for a in mesh.axis_names])),
            "param_count": sum(
                int(x.size) for x in jax.tree_util.tree_leaves(pshape)),
            "seq_len": sh["seq_len"], "global_batch": sh["global_batch"]}

    if kind == "train":
        opt_cfg = AdamWConfig(factored_second_moment=(
            meta["param_count"] > 1e11))
        oshape = jax.eval_shape(
            functools.partial(adamw_init, cfg=opt_cfg), pshape)
        oshard = make_shardings(opt_state_specs(oshape, pspecs, mesh), mesh)
        step_fn = steps_lib.make_train_step(
            cfg, opt_cfg, cosine_warmup(3e-4, 100, 10_000))
        jitted = jax.jit(step_fn,
                         in_shardings=(pshard, oshard, bshard, None),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))

        def lower():
            return jitted.lower(pshape, oshape, batch,
                                jax.ShapeDtypeStruct((), jnp.int32))
        return lower, meta

    if kind == "prefill":
        step_fn = steps_lib.make_prefill_step(cfg, max_len=sh["seq_len"])
        jitted = jax.jit(step_fn, in_shardings=(pshard, bshard))

        def lower():
            return jitted.lower(pshape, batch)
        return lower, meta

    # decode
    b = sh["global_batch"]
    sshape = jax.eval_shape(
        functools.partial(lm.init_serve_state, cfg, b, sh["seq_len"]))
    sshard = make_shardings(serve_state_specs(sshape, mesh), mesh)
    step_fn = steps_lib.make_decode_step(cfg)
    jitted = jax.jit(step_fn,
                     in_shardings=(pshard, bshard["token"], sshard),
                     out_shardings=(None, sshard),
                     donate_argnums=(2,))

    def lower():
        return jitted.lower(pshape, batch["token"], sshape)
    return lower, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, kernel: str,
             outdir: str, force: bool = False, remat: str = "dots",
             tag: str = "", preset: str = "2d",
             shard_features: bool = False) -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    os.makedirs(outdir, exist_ok=True)
    fname = os.path.join(
        outdir, f"{arch}__{shape_name}__{mesh_name}"
        + (f"__{tag}" if tag else "") + ".json")
    if os.path.exists(fname) and not force:
        with open(fname) as f:
            return json.load(f)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    rec: dict = {}
    try:
        lower_fn, meta = build_cell(arch, shape_name, mesh, kernel, remat,
                                    preset=preset,
                                    shard_features=shard_features)
        rec.update(meta)
        if lower_fn is None:
            rec["status"] = "skipped"
        else:
            t0 = time.time()
            with jax.set_mesh(mesh):
                lowered = lower_fn()
            rec["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 2)
            try:
                mem = compiled.memory_analysis()
                rec["memory"] = {
                    k: int(getattr(mem, k))
                    for k in ("argument_size_in_bytes",
                              "output_size_in_bytes",
                              "temp_size_in_bytes",
                              "generated_code_size_in_bytes")
                    if hasattr(mem, k)}
            except Exception as e:          # CPU backend gaps are fine
                rec["memory"] = {"error": str(e)}
            try:
                cost = compiled.cost_analysis()
                if isinstance(cost, list):
                    cost = cost[0]
                rec["cost"] = {k: float(v) for k, v in cost.items()
                               if isinstance(v, (int, float))
                               and k in ("flops", "bytes accessed",
                                         "transcendentals",
                                         "utilization operand 0 {}",
                                         "optimal_seconds")}
                rec["flops"] = float(cost.get("flops", 0.0))
                rec["bytes_accessed"] = float(cost.get("bytes accessed",
                                                       0.0))
            except Exception as e:
                rec["cost"] = {"error": str(e)}
            try:
                hlo = compiled.as_text()
                rec["collectives"] = collective_bytes(hlo)
                rec["hlo_bytes"] = len(hlo)
            except Exception as e:
                rec["collectives"] = {"error": str(e)}
            rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _extract_costs(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collective_total": float(coll["total"]),
            "collectives": coll}


def run_cost_probe(arch: str, shape_name: str, kernel: str, outdir: str,
                   force: bool = False, remat: str = "dots",
                   tag: str = "", preset: str = "2d",
                   shard_features: bool = False,
                   features: int = 0, pin_moe: bool = False) -> dict:
    """Exact per-device cost extrapolation for scanned stacks.

    XLA's HloCostAnalysis counts a while-loop body ONCE (verified: a scan
    of 8 matmuls reports 1 matmul of flops), so the scanned-stack module
    costs undercount in-loop work by ~n_units x. This probe lowers the
    same cell UNROLLED at 1 and 2 pattern-units (+ remainder layers) on the
    single-pod mesh; the unit difference is the exact per-unit cost and

        total = outside + n_units * unit,   outside = probe1 - unit

    which reconstructs flops / bytes / collective-bytes for the full
    depth. Residual undercount: bodies of *inner* time scans (the chunked
    linear-attention scan, RWKV's wkv scan) — ~1-3% of flops (see
    EXPERIMENTS.md §Roofline notes).
    """
    os.makedirs(outdir, exist_ok=True)
    fname = os.path.join(
        outdir, f"{arch}__{shape_name}__probe"
        + (f"__{tag}" if tag else "") + ".json")
    if os.path.exists(fname) and not force:
        with open(fname) as f:
            return json.load(f)
    rec: dict = {"arch": arch, "shape": shape_name, "probe": True,
                 "tag": tag, "preset": preset,
                 "shard_features": shard_features}
    try:
        mesh = mesh_lib.make_production_mesh(multi_pod=False)
        cfg_full = cfgs.get_config(arch)
        if kernel:
            cfg_full = cfgs.darkify(cfg_full, kernel,
                                    features or cfg_full.attn.num_features)
        cfg_full = dataclasses.replace(cfg_full, remat=remat)
        plen = len(cfg_full.block_pattern)
        rem = cfg_full.n_rem
        probes = []
        if pin_moe and cfg_full.moe is not None:
            from repro.parallel.sharding import dp_axes
            eax = ("model" if cfg_full.moe.num_experts %
                   mesh.shape["model"] == 0 else None)
            cfg_full = dataclasses.replace(
                cfg_full, moe=dataclasses.replace(
                    cfg_full.moe, dispatch_spec=(dp_axes(mesh), eax)))
        for units in (1, 2):
            cfg_p = dataclasses.replace(
                cfg_full, n_layers=plen * units + rem, scan_layers=False)
            lower_fn, meta = build_cell(arch, shape_name, mesh, kernel,
                                        remat, cfg=cfg_p, preset=preset,
                                        shard_features=shard_features,
                                        pin_moe=pin_moe)
            if lower_fn is None:
                rec["status"] = "skipped"
                rec["skipped"] = meta.get("skipped", "")
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                return rec
            t0 = time.time()
            with jax.set_mesh(mesh):
                compiled = lower_fn().compile()
            probes.append(_extract_costs(compiled))
            probes[-1]["compile_s"] = round(time.time() - t0, 2)
        u = cfg_full.n_units
        extrap = {}
        for k in ("flops", "bytes_accessed", "collective_total"):
            unit = max(probes[1][k] - probes[0][k], 0.0)
            outside = max(probes[0][k] - unit, 0.0)
            extrap[k] = outside + u * unit
            extrap[k + "_unit"] = unit
            extrap[k + "_outside"] = outside
        rec.update({"status": "ok", "n_units": u, "probes": probes,
                    "extrapolated": extrap})
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--kernel", default="darkformer")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="run the unrolled 2-point cost probe (exact "
                         "flops/bytes/collectives for §Roofline)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--preset", default="2d", choices=["2d", "fsdp"])
    ap.add_argument("--shard-features", action="store_true")
    ap.add_argument("--features", type=int, default=0,
                    help="override PRF feature count m (probe only)")
    ap.add_argument("--pin-moe", action="store_true",
                    help="pin MoE dispatch buffers' sharding (perf exp)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()

    archs = cfgs.ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = (["pod", "multipod"] if args.mesh == "both"
              else [args.mesh])
    n_ok = n_skip = n_err = 0
    if args.probe:
        for arch in archs:
            for shape in shapes:
                t0 = time.time()
                rec = run_cost_probe(arch, shape, args.kernel, args.outdir,
                                     args.force, args.remat, args.tag,
                                     args.preset, args.shard_features,
                                     args.features, args.pin_moe)
                status = rec.get("status")
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                line = (f"[probe {arch} x {shape}] {status} "
                        f"({time.time()-t0:.1f}s)")
                if status == "ok":
                    e = rec["extrapolated"]
                    line += (f" flops={e['flops']:.3e}"
                             f" coll={e['collective_total']:.3e}B")
                elif status == "error":
                    line += " :: " + rec.get("error", "")[:200]
                print(line, flush=True)
        print(f"probe summary: ok={n_ok} skipped={n_skip} errors={n_err}")
        raise SystemExit(1 if n_err else 0)
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_name == "multipod",
                               args.kernel, args.outdir, args.force,
                               args.remat, args.tag, args.preset,
                               args.shard_features)
                status = rec.get("status")
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_err += status == "error"
                line = (f"[{arch} x {shape} x {mesh_name}] {status} "
                        f"({time.time()-t0:.1f}s)")
                if status == "ok":
                    line += (f" flops={rec.get('flops', 0):.3e}"
                             f" coll={rec.get('collectives', {}).get('total', 0):.3e}B"
                             f" compile={rec.get('compile_s')}s")
                elif status == "error":
                    line += " :: " + rec.get("error", "")[:200]
                print(line, flush=True)
    print(f"dryrun summary: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
