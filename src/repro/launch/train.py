"""Training launcher (end-to-end driver, deliverable (b)).

Runs real training on whatever devices exist (CPU here; the same code path
works under a TPU mesh — the mesh/sharding logic is shared with dryrun.py).
Features: pjit + sharding rules, checkpoint/restart via TrainSupervisor,
failure injection, preemption handling, the paper's finetuning modes
(--finetune-from, --qkv-only), and kernel switching (--kernel).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 200 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch darkformer-2b \
      --reduced --kernel performer --steps 100
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.data import SyntheticLM, SyntheticAudio, SyntheticVLM, C4Mock
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init
from repro.optim.schedules import cosine_warmup
from repro.parallel import (param_specs, opt_state_specs, batch_specs,
                            make_shardings)
from repro.runtime import TrainSupervisor, StragglerMonitor, \
    PreemptionHandler
from repro import checkpoint as ckpt_lib


def make_data(cfg, args):
    if cfg.modality == "audio":
        return SyntheticAudio(cfg.d_model, args.seq, args.batch,
                              vocab=cfg.vocab, seed=args.seed)
    if cfg.modality == "vlm":
        return SyntheticVLM(cfg.d_model, cfg.num_patches, args.seq,
                            args.batch, cfg.vocab, seed=args.seed)
    if args.data == "c4mock":
        return C4Mock(cfg.vocab, args.seq, args.batch, seed=args.seed)
    return SyntheticLM(cfg.vocab, args.seq, args.batch, seed=args.seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--kernel", default=None,
                    help="override attention kernel "
                         "(exact|performer|darkformer|lfk|random|constant)")
    ap.add_argument("--features", type=int, default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "c4mock"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--finetune-from", default=None,
                    help="checkpoint dir with pretrained params")
    ap.add_argument("--qkv-only", action="store_true",
                    help="paper Fig.4: train only q/k/v + PRF covariance")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg = cfgs.get_config(args.arch, reduced=args.reduced)
    if args.kernel:
        cfg = cfgs.darkify(cfg, args.kernel,
                           args.features or cfg.attn.num_features)
    mesh = mesh_lib.make_local_mesh(args.mesh_data, args.mesh_model)

    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.finetune_from:
        # supervisor checkpoints store {"params", "opt"}; restore params
        # only (fresh optimizer for the finetune phase).
        wrapped, step0 = ckpt_lib.restore_checkpoint(
            args.finetune_from, {"params": params})
        params = wrapped["params"]
        print(f"finetuning from {args.finetune_from} @ step {step0}")
    opt_cfg = AdamWConfig(lr=args.lr)
    opt_state = adamw_init(params, opt_cfg)

    pspecs = param_specs(params, mesh, moe=cfg.moe is not None)
    pshard = make_shardings(pspecs, mesh)
    oshard = make_shardings(
        opt_state_specs(opt_state, pspecs, mesh), mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, pshard)
    opt_state = jax.tree_util.tree_map(jax.device_put, opt_state, oshard)

    schedule = cosine_warmup(args.lr, args.warmup, args.steps)
    freeze = steps_lib.qkv_only_freeze if args.qkv_only else None
    raw_step = steps_lib.make_train_step(cfg, opt_cfg, schedule, freeze)
    data = make_data(cfg, args)
    batch0 = data.batch(0)
    bshard = make_shardings(batch_specs(batch0, mesh), mesh)
    jitted = jax.jit(raw_step,
                     in_shardings=(pshard, oshard, bshard, None),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))

    metrics_log = []

    def step_fn(state, step):
        params, opt_state = state["params"], state["opt"]
        batch = jax.tree_util.tree_map(
            jax.device_put, dict(data.batch(step)), bshard)
        params, opt_state, metrics = jitted(params, opt_state, batch,
                                            jnp.int32(step))
        state = {"params": params, "opt": opt_state}
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            metrics_log.append(m)
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"acc {m['accuracy']:.4f} gnorm {m['grad_norm']:.3f}",
                  flush=True)
        return state

    state = {"params": params, "opt": opt_state}
    t0 = time.time()
    if args.ckpt_dir:
        sup = TrainSupervisor(args.ckpt_dir, ckpt_every=args.ckpt_every,
                              monitor=StragglerMonitor(),
                              preemption=PreemptionHandler())
        state = sup.run(state, step_fn, args.steps,
                        fail_at=args.simulate_failure_at)
        if sup.monitor.straggler_steps:
            print(f"stragglers flagged: {sup.monitor.straggler_steps}")
    else:
        for step in range(args.steps):
            state = step_fn(state, step)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(metrics_log, f, indent=1)


if __name__ == "__main__":
    main()
