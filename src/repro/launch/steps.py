"""Step builders shared by train.py / serve.py / dryrun.py."""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg: lm.ModelConfig, opt_cfg: AdamWConfig,
                    schedule: Callable, freeze: Optional[Callable] = None):
    """Returns train_step(params, opt_state, batch, step) -> (p, o, metrics).

    ``freeze`` is a predicate over the param tree-path string: True means
    the leaf's gradient is zeroed (the paper's limited-attention finetuning
    freezes everything but q/k/v and the PRF covariance M).
    """

    def train_step(params, opt_state, batch, step):
        rng = jax.random.fold_in(jax.random.PRNGKey(0), step)
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, cfg, batch, rng)
        if freeze is not None:
            flat, tdef = jax.tree_util.tree_flatten_with_path(grads)
            flat = [(p, jnp.zeros_like(g)
                     if freeze(jax.tree_util.keystr(p)) else g)
                    for p, g in flat]
            grads = jax.tree_util.tree_unflatten(tdef,
                                                 [g for _, g in flat])
        lr = schedule(step)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, lr)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_eval_step(cfg: lm.ModelConfig):
    def eval_step(params, batch):
        _, metrics = lm.loss_fn(params, cfg, batch)
        return metrics
    return eval_step


def make_prefill_step(cfg: lm.ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, max_len)
    return prefill_step


def make_decode_step(cfg: lm.ModelConfig):
    def serve_step(params, token, state):
        return lm.decode_step(params, cfg, token, state)
    return serve_step


# The paper's limited-attention finetuning (Fig. 4): train only q/k/v
# projections and the DARKFormer covariance M (plus the PRF projection W in
# lfk mode).
def qkv_only_freeze(path: str) -> bool:
    keep = ("['wq']", "['wk']", "['wv']", "['m_mat']")
    return not any(k in path for k in keep)
