"""Distribution: sharding rules, gradient compression, SP helpers."""
from repro.parallel.sharding import (param_specs, opt_state_specs,
                                     batch_specs, serve_state_specs,
                                     make_shardings, dp_axes,
                                     constrain_batch_axis)
from repro.parallel.compression import (compressed_psum_mean,
                                        init_error_feedback)
