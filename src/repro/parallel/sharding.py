"""Sharding rules: logical param/batch/state layouts -> PartitionSpecs.

Axis roles (DESIGN §6):
  pod    — data parallelism across pods (DCN)
  data   — data parallelism + FSDP/ZeRO param sharding (ICI)
  model  — tensor/expert parallelism (ICI)

Rules are matched on parameter tree paths. Scanned-unit params carry a
leading n_units dim which gets a None prefix automatically (detected via
the "units" path component). Activations are constrained on the batch axis
at block boundaries; internals are left to XLA SPMD propagation from the
weight specs (MaxText-style).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# (regex over keystr path, spec WITHOUT the scan-unit prefix)
#
# RULE OF THUMB (learned the hard way — see EXPERIMENTS.md §Perf): never
# shard a matmul CONTRACTION dim over 'data'. The batch is data-sharded,
# so a d-over-data weight makes XLA SPMD either all-reduce activations
# over 'data' or replicate the batch (observed: full-batch f32 buffers,
# 12.9 GB logits all-reduces). Weight dims sharded over 'data' must be
# non-contraction dims (ZeRO-style weight all-gather, bytes = params).
_PARAM_RULES: list[tuple[str, P]] = [
    (r"\['embed'\]$",               P("model", None)),     # vocab x d
    (r"\['lm_head'\]$",             P(None, "model")),
    (r"\['mask_embed'\]$",          P(None)),
    (r"\['attn'\]\['wq'\]$",        P(None, ("model", "data"))),
    (r"\['attn'\]\['wk'\]$",        P(None, ("model", "data"))),
    (r"\['attn'\]\['wv'\]$",        P(None, ("model", "data"))),
    (r"\['attn'\]\['wo'\]$",        P("model", "data")),
    (r"\['feat'\]\['w'\]$",         P(None, None, None)),  # (G, m, r) small
    (r"\['feat'\]\['m_mat'\]$",     P(None, None, None)),
    (r"\['(q_norm|k_norm)'\]\['scale'\]$", P(None)),
    # dense mlp
    (r"\['ffn'\]\['w_gate'\]$",     P(None, ("model", "data"))),
    (r"\['ffn'\]\['w_up'\]$",       P(None, ("model", "data"))),
    (r"\['ffn'\]\['w_out'\]$",      P("model", "data")),
    # moe (E, d, f) / (E, f, d): experts on model (EP), d_model on data
    (r"\['ffn'\]\['router'\]$",     P(None, None)),
    # rg-lru
    (r"\['rec'\]\['wx'\]$",         P(None, ("model", "data"))),
    (r"\['rec'\]\['wg'\]$",         P(None, ("model", "data"))),
    (r"\['rec'\]\['conv_w'\]$",     P(None, "model")),
    (r"\['rec'\]\['wa'\]$",         P("model", None)),
    (r"\['rec'\]\['wi'\]$",         P("model", None)),
    (r"\['rec'\]\['lam'\]$",        P("model")),
    (r"\['rec'\]\['wo'\]$",         P("model", "data")),
    # rwkv
    (r"\['tmix'\]\['w[rkvg]'\]$",   P(None, ("model", "data"))),
    (r"\['tmix'\]\['wo'\]$",        P("model", "data")),
    (r"\['tmix'\]\['decay_a'\]$",   P(None, None)),
    (r"\['tmix'\]\['decay_b'\]$",   P(None, "model")),
    (r"\['tmix'\]\['u'\]$",         P(None, None)),
    (r"\['tmix'\]\['mu'\]$",        P(None, None)),
    (r"\['tmix'\]\['lam_w'\]$",     P(None)),
    (r"\['tmix'\]\['ln_x'\]",       P(None)),
    (r"\['cmix'\]\['wk'\]$",        P(None, ("model", "data"))),
    (r"\['cmix'\]\['wv'\]$",        P("model", "data")),
    (r"\['cmix'\]\['wr'\]$",        P(None, ("model", "data"))),
    (r"\['cmix'\]\['mu'\]$",        P(None, None)),
    # norms / misc
    (r"\['scale'\]$",               P(None)),
    (r"\['bias'\]$",                P(None)),
]

_MOE_RULES: list[tuple[str, P]] = [
    (r"\['ffn'\]\['w_gate'\]$",     P("model", None, "data")),
    (r"\['ffn'\]\['w_up'\]$",       P("model", None, "data")),
    (r"\['ffn'\]\['w_out'\]$",      P("model", None, "data")),
]

# When num_experts doesn't divide the model axis (e.g. granite-moe's 40
# experts on a 16-way axis) the EP spec above gets dropped by _divisible
# and expert compute would run REPLICATED across 'model' (16x redundant
# flops — caught by the §Roofline useful-flops ratio). Fall back to
# sharding the per-expert hidden dim over 'model' instead (TP inside each
# expert; dispatch stays data-local).
_MOE_RULES_TP: list[tuple[str, P]] = [
    (r"\['ffn'\]\['w_gate'\]$",     P(None, None, "model")),
    (r"\['ffn'\]\['w_up'\]$",       P(None, None, "model")),
    (r"\['ffn'\]\['w_out'\]$",      P(None, "model", None)),
]


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _match(path_str: str, moe: bool, moe_tp: bool = False) -> Optional[P]:
    if moe:
        rules = _MOE_RULES_TP if moe_tp else _MOE_RULES
        for pat, spec in rules:
            if re.search(pat, path_str):
                return spec
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path_str):
            return spec
    return None


def _divisible(shape, spec: P, mesh: Mesh) -> P:
    """Drop sharding on dims that don't divide evenly (keeps lowering legal
    for small dims like MQA kv heads)."""
    new = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) -
                                                       len(spec))):
        if ax is None:
            new.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        new.append(ax if dim % size == 0 else None)
    return P(*new)


def param_specs(params: PyTree, mesh: Mesh, moe: bool = False,
                preset: str = "2d", shard_features: bool = False,
                overrides: tuple = ()) -> PyTree:
    """PartitionSpec tree mirroring ``params`` (works on ShapeDtypeStructs).

    preset:
      "2d"   — TP over 'model' + FSDP over 'data' (the rule table above).
      "fsdp" — no tensor parallelism: every >=2-D param sharded on its
               largest dim over the combined ('data','model') axes
               (ZeRO-3); batch must then also span both axes.
    shard_features — shard the PRF feature dim m of the per-group
      projection W over 'model' (perf experiment: distributes the
      (L x m) feature activations and the (m x dv) scan state).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    # detect EP-infeasible expert counts once (see _MOE_RULES_TP)
    moe_tp = False
    if moe:
        for path, leaf in flat:
            ps = jax.tree_util.keystr(path)
            if ps.endswith("['ffn']['w_gate']"):
                e_dim = leaf.shape[1 if "['units']" in ps else 0]
                moe_tp = e_dim % mesh.shape["model"] != 0
                break
    specs = []
    for path, leaf in flat:
        ps = jax.tree_util.keystr(path)
        shape = tuple(leaf.shape)
        scanned = "['units']" in ps
        if preset == "fsdp":
            body = shape[1:] if scanned else shape
            if len(body) >= 2:
                big = max(range(len(body)), key=lambda i: body[i])
                t = [None] * len(body)
                t[big] = ("data", "model")
                spec = P(*t)
            else:
                spec = P(*([None] * len(body)))
            if scanned:
                spec = P(*((None,) + tuple(spec)))
            specs.append(_divisible(shape, spec, mesh))
            continue
        spec = None
        for pat, tspec in overrides:
            if re.search(pat, ps):
                spec = P(*tspec)
                break
        if spec is None:
            spec = _match(ps, moe, moe_tp)
        if shard_features and "['feat']" in ps:
            # (G, m, r) / (G, r, d): shard m (W's dim -2) over model
            spec = P(None, "model", None) if ps.endswith("['w']") else spec
        if spec is None:
            spec = P(*([None] * len(shape)))
        if scanned:
            spec = P(*((None,) + tuple(spec)))
        # pad/truncate to rank
        t = tuple(spec)[: len(shape)]
        t = t + (None,) * (len(shape) - len(t))
        specs.append(_divisible(shape, P(*t), mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_specs(opt_state: PyTree, pspecs: PyTree, mesh: Mesh) -> PyTree:
    """Optimizer-state specs mirror the param specs (mu/nu shadow params;
    factored nu rows/cols inherit the reduced spec; count replicated)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_state)
    pflat = {jax.tree_util.keystr(p): s
             for p, s in jax.tree_util.tree_flatten_with_path(pspecs)[0]}
    specs = []
    for path, leaf in flat:
        ps = jax.tree_util.keystr(path)
        if ps.endswith("['count']"):
            specs.append(P())
            continue
        # strip the leading ['mu'] / ['nu'] component and factored suffix
        inner = ps.split("]", 1)[1]
        suffix = None
        if inner.endswith("['row']") or inner.endswith("['col']"):
            suffix = inner[-6:-2]
            inner = inner[: -len("['row']")]
        base = pflat.get(inner)
        if base is None:
            specs.append(P(*([None] * leaf.ndim)))
            continue
        t = tuple(base)
        if suffix == "row":            # param shape minus last dim
            t = t[:-1]
        elif suffix == "col":          # minus second-to-last dim
            t = t[:-2] + t[-1:]
        t = t[: leaf.ndim] + (None,) * max(0, leaf.ndim - len(t))
        specs.append(_divisible(tuple(leaf.shape), P(*t), mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch: PyTree, mesh: Mesh, preset: str = "2d") -> PyTree:
    """Shard the leading batch dim over the DP axes (replicate if it does
    not divide — e.g. the long_500k single-sequence cell). Under the
    "fsdp" preset the batch spans ('data','model') too."""
    dp = dp_axes(mesh)
    if preset == "fsdp":
        dp = dp + ("model",)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def spec(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        if shape[0] % dp_size == 0 and shape[0] > 0:
            return P(dp, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))
    return jax.tree_util.tree_map(spec, batch)


def serve_state_specs(state: PyTree, mesh: Mesh) -> PyTree:
    """Serving state: batch on DP axes where divisible; the KV-cache /
    linear-state head-group dim additionally on 'model' where divisible."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)

    def spec(path, leaf):
        ps = jax.tree_util.keystr(path)
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        # scanned leading layer dim ('units' per-unit stacks, 'layers'
        # the layer-stacked homogeneous layout)
        off = 1 if ("['units']" in ps or "['layers']" in ps) else 0
        axes: list = [None] * len(shape)
        if len(shape) > off and shape[off] % dp_size == 0:
            axes[off] = dp
        # group/head dim right after batch for kv caches & linear states
        if len(shape) > off + 1 and shape[off + 1] % msize == 0 and \
                any(t in ps for t in ("kv_k", "kv_v", "'s'", "'z'", "'c'")):
            axes[off + 1] = "model"
        return P(*axes)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def make_shardings(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def constrain_batch_axis(x, mesh: Mesh):
    """with_sharding_constraint on the leading batch dim (block boundaries)."""
    dp = dp_axes(mesh)
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
