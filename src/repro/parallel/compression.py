"""Gradient compression for cross-pod reduction (bf16 / int8 + error feedback).

At 2+ pods the gradient all-reduce crosses the DCN (much thinner than ICI);
compressing the payload 2x (bf16) or 4x (int8) directly scales the
collective term of the roofline. int8 uses per-tensor max-abs scaling with
an error-feedback accumulator (Seide et al.; Karimireddy et al. 2019) so the
quantization noise is compensated in the next step instead of biasing the
update.

Usage (inside a shard_map'd train step over the DP axes):
    grads, eb = compressed_psum_mean(grads, ("pod", "data"), method, eb)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def init_error_feedback(grads: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _axis_size(a):
    # jax.lax.axis_size only exists on newer jax; psum of a unit scalar is
    # the portable spelling (constant-folded, no collective emitted).
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(jnp.ones((), jnp.int32), a)


def _psum_mean(x, axis_names):
    y = jax.lax.psum(x, axis_names)
    n = 1
    for a in (axis_names if isinstance(axis_names, tuple) else
              (axis_names,)):
        n = n * _axis_size(a)
    return y / n


def compressed_psum_mean(grads: PyTree, axis_names, method: str = "none",
                         error_feedback: Optional[PyTree] = None
                         ) -> tuple[PyTree, Optional[PyTree]]:
    """Mean-all-reduce grads over ``axis_names`` with optional compression.

    method: none | bf16 | int8. Returns (grads, new_error_feedback).
    Must be called inside shard_map with those axes in scope.
    """
    if method == "none":
        return jax.tree_util.tree_map(
            lambda g: _psum_mean(g, axis_names), grads), error_feedback

    if method == "bf16":
        def red(g):
            return _psum_mean(g.astype(jnp.bfloat16).astype(jnp.float32),
                              axis_names).astype(g.dtype)
        return jax.tree_util.tree_map(red, grads), error_feedback

    if method == "int8":
        assert error_feedback is not None, "int8 needs error feedback"

        def red(g, eb):
            gf = g.astype(jnp.float32) + eb
            scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(gf / scale), -127, 127)
            deq = q * scale
            new_eb = gf - deq                      # local residual
            # int8 payload on the wire; psum in f32 of the dequantized
            # value is what XLA will emit — we model payload size in the
            # roofline by the int8 cast below.
            reduced = _psum_mean(deq, axis_names)
            return reduced.astype(g.dtype), new_eb

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_flatten(error_feedback)[0]
        out = [red(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        return new_g, new_e

    raise ValueError(f"unknown compression method {method!r}")
