"""Checkpointing: msgpack pytree snapshots, atomic, keep-k, elastic restore."""
from repro.checkpoint.store import (save_checkpoint, restore_checkpoint,
                                    latest_step, all_steps,
                                    restore_to_shardings)
