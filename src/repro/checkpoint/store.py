"""Msgpack pytree checkpoints (no orbax in this container).

Layout:  <dir>/step_<N>/state.msgpack   (+ DONE marker)
Guarantees:
  * atomic: written to step_<N>.tmp-<pid>, fsync'd, then os.replace'd —
    a crash mid-write never corrupts the latest checkpoint;
  * keep-last-k garbage collection;
  * multi-host: only process 0 writes (others return); restore is
    host-local (all hosts read the same file — fine for replicated or
    host-sharded reload via ``restore_to_shardings``);
  * elastic: ``restore_to_shardings`` device_puts each leaf with a target
    NamedSharding, so a checkpoint written on one mesh reloads onto any
    other mesh topology (shrunk/grown cluster) — the resharding collective
    is XLA's problem, not ours.

Arrays are stored as (dtype, shape, raw bytes); the pytree structure is
serialized from tree paths, so save/restore does not need an example tree
(but will validate against one if given).
"""
from __future__ import annotations

import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _pack_leaf(arr: np.ndarray) -> dict:
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack_leaf(rec: dict) -> np.ndarray:
    return np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(
        rec["shape"])


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    keep: int = 3) -> str:
    """Write step checkpoint atomically; GC to the newest ``keep``."""
    if jax.process_index() != 0:
        return os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + f".tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    payload = {k: _pack_leaf(v) for k, v in _flatten(tree).items()}
    fpath = os.path.join(tmp, "state.msgpack")
    with open(fpath, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    open(os.path.join(tmp, "DONE"), "w").close()
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and "tmp" not in name:
            full = os.path.join(ckpt_dir, name)
            if os.path.exists(os.path.join(full, "DONE")):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def _load_payload(ckpt_dir: str, step: int) -> dict[str, np.ndarray]:
    fpath = os.path.join(ckpt_dir, f"step_{step}", "state.msgpack")
    with open(fpath, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    return {k: _unpack_leaf(v) for k, v in payload.items()}


def restore_checkpoint(ckpt_dir: str, target: PyTree,
                       step: Optional[int] = None) -> tuple[PyTree, int]:
    """Restore into the structure of ``target``. Returns (tree, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    data = _load_payload(ckpt_dir, step)
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for path, leaf in paths:
        key = jax.tree_util.keystr(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(jnp.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs target "
                f"{jnp.shape(leaf)}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype if hasattr(
            leaf, "dtype") else arr.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def restore_to_shardings(ckpt_dir: str, target: PyTree, shardings: PyTree,
                         step: Optional[int] = None) -> tuple[PyTree, int]:
    """Elastic restore: place every leaf with its target NamedSharding.

    ``shardings`` mirrors ``target`` (leaves = jax.sharding.Sharding).
    Works across mesh topologies — this is the restart-after-resize path.
    """
    tree, step = restore_checkpoint(ckpt_dir, target, step)
    placed = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
    return placed, step
