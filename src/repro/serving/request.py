"""Request/result records for the continuous-batching serving engine.

A ``Request`` is what a client submits: a token prompt plus decode
parameters. A ``RequestResult`` is what the engine hands back: the
generated tokens plus the wall-clock trace (arrival -> admission ->
per-token -> finish) that the latency benchmarks aggregate into
TTFT / per-token percentiles (benchmarks/serve_latency.py).

Timing contract: every entry of ``token_times`` is a token *readiness*
time — the engine records it only after blocking on the device buffer
that holds the token, never at dispatch. Under the overlapped step loop
(``ServingEngine(overlap=True)``) tokens are sampled into a device
buffer that the host fetches one step later, so the token value (and
its ``on_token`` callback, below) arrives one engine step after the
decode that produced it; the recorded time is when the host observed
the ready value, an upper bound on device completion that coincides
with it whenever the host is the one waiting.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from typing import Callable, Optional, Sequence

_uid_counter = itertools.count()


def next_uid() -> int:
    return next(_uid_counter)


@dataclasses.dataclass
class Request:
    """One generation request.

    ``arrival_time`` is in seconds relative to the engine's clock start;
    the scheduler will not admit a request before it "arrives" (used by
    the Poisson-traffic benchmark; 0.0 = immediately available).
    ``temperature`` 0.0 means greedy decoding (deterministic — this is
    what the parity tests use). ``top_k`` (0 = off) and ``top_p``
    (1.0 = off) restrict temperature sampling to the k highest-logit /
    smallest p-mass nucleus tokens per step; they are applied per slot
    row inside the engine's jitted sample step and leave greedy decoding
    untouched.

    ``on_token`` is the delayed-token stream hook: the engine calls it
    as ``on_token(token, t)`` for every generated token at the moment
    the token becomes *ready on the host* (see module docstring) — in
    arrival order, before the token is appended to the result. Under
    the overlapped loop this fires one engine step after the producing
    decode; in-flight tokens of a cancelled request are dropped without
    a callback. Exceptions propagate out of ``step()``.
    """
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: Optional[int] = None
    arrival_time: float = 0.0
    uid: int = dataclasses.field(default_factory=next_uid)
    on_token: Optional[Callable[[int, float], None]] = None


@dataclasses.dataclass
class RequestResult:
    """Completed (or cancelled) request with its timing trace."""
    uid: int
    prompt: list[int]
    tokens: list[int] = dataclasses.field(default_factory=list)
    arrival_time: float = 0.0
    admit_time: float = 0.0          # when the slot prefill finished
    finish_time: float = 0.0
    token_times: list[float] = dataclasses.field(default_factory=list)
    cancelled: bool = False

    @property
    def ttft(self) -> float:
        """Time-to-first-token: arrival -> first generated token."""
        if not self.token_times:
            return float("nan")
        return self.token_times[0] - self.arrival_time

    @property
    def tpots(self) -> list[float]:
        """Per-token latencies after the first (time-per-output-token)."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]


def synthetic_requests(n: int, vocab: int, *, seed: int = 0,
                       rate: float = 0.0,
                       prompt_range: tuple[int, int] = (16, 64),
                       gen_range: tuple[int, int] = (16, 32),
                       temperature: float = 0.0, top_k: int = 0,
                       top_p: float = 1.0) -> list[Request]:
    """Random-token request stream shared by the serve CLI and the
    serving benchmarks. ``rate`` > 0 spaces arrivals by an exponential
    (Poisson process) clock; 0 makes everything available at t=0."""
    rng = random.Random(seed)
    t, reqs = 0.0, []
    for _ in range(n):
        if rate > 0:
            t += rng.expovariate(rate)
        reqs.append(Request(
            prompt=[rng.randrange(vocab)
                    for _ in range(rng.randint(*prompt_range))],
            max_new_tokens=rng.randint(*gen_range),
            temperature=temperature, top_k=top_k, top_p=top_p,
            arrival_time=t))
    return reqs


def shared_prefix_requests(n: int, vocab: int, *, seed: int = 0,
                           rate: float = 0.0, prefix_len: int = 96,
                           n_prefixes: int = 1, reuse: float = 0.8,
                           suffix_range: tuple[int, int] = (16, 32),
                           gen_range: tuple[int, int] = (16, 32),
                           temperature: float = 0.0) -> list[Request]:
    """Prefix-heavy request stream: a ``reuse`` fraction of requests
    open with one of ``n_prefixes`` shared ``prefix_len``-token prompts
    (the system-prompt / few-shot template traffic shape the prefix
    cache targets — benchmarks/serve_latency.py part 6) followed by a
    private random suffix; the rest are fully random control prompts of
    the same total length. ``rate`` spaces arrivals like
    :func:`synthetic_requests`."""
    rng = random.Random(seed)
    prefixes = [[rng.randrange(vocab) for _ in range(prefix_len)]
                for _ in range(n_prefixes)]
    t, reqs = 0.0, []
    for _ in range(n):
        if rate > 0:
            t += rng.expovariate(rate)
        suffix = [rng.randrange(vocab)
                  for _ in range(rng.randint(*suffix_range))]
        if rng.random() < reuse:
            prompt = rng.choice(prefixes) + suffix
        else:
            prompt = [rng.randrange(vocab)
                      for _ in range(prefix_len)] + suffix
        reqs.append(Request(prompt=prompt,
                            max_new_tokens=rng.randint(*gen_range),
                            temperature=temperature, arrival_time=t))
    return reqs
