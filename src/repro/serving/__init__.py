"""Continuous-batching serving over the paper's O(1)-state PRF decode.

Public surface:

  * ``Request`` / ``RequestResult`` — what clients submit and get back
  * ``ServingEngine``               — queue + slot pool + batched decode
  * ``slots``                       — slot-pool pytree primitives
  * ``PrefixCache`` / ``PrefixCacheConfig`` — prefix snapshot store
    behind ``ServingEngine(prefix_cache=...)`` fork-on-admit reuse
    (``PageAllocator`` manages the exact paged-KV page pool)

Design doc: docs/serving.md. The CLI front-end is
``python -m repro.launch.serve``.
"""
from repro.serving import slots
from repro.serving.engine import ServingEngine
from repro.serving.prefix_cache import (NoFreePages, PageAllocator,
                                        PrefixCache, PrefixCacheConfig)
from repro.serving.request import Request, RequestResult
