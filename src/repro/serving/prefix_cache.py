"""Prefix cache: prefix-hash → state-snapshot store with O(1) forking.

Production traffic is dominated by shared prefixes (system prompts,
few-shot templates, multi-turn history). The PRF kinds make reuse
uniquely cheap: a prefix's whole attention state is the fixed-size
(S, z, c) tuple per layer, so "fork a cached prefix into N requests"
is ONE slot-pool broadcast scatter (``slots.fork_slots``) — no paged KV
copy, no allocator, context-length-independent snapshot bytes.

This module owns the store; the engine owns the fork
(repro/serving/engine.py):

  * **Keys** — ``blake2b`` over the prefix's int32 token bytes. Entries
    keep the token tuple and verify it on lookup, so a hash collision
    can never splice the wrong state into a request. Snapshots are
    captured when a request's prefill cursor crosses a
    ``block_tokens``-aligned boundary (and, optionally, at prompt
    completion — the multi-turn case); lookups try exactly the prefix
    lengths present in the store, longest first, hashing them in one
    rolling pass. A match must leave at least one prompt token unprefilled
    (the engine samples the first output token from real final-chunk
    logits, never from a cached state).
  * **Tiers** — snapshots are born on DEVICE (they are gathered out of
    the staging pool and fork back in without a host round-trip). When
    the device tier exceeds ``device_bytes`` the LRU entries demote to
    HOST numpy; when the host tier exceeds ``host_bytes`` they are
    evicted. A host hit is promoted back through the engine-supplied
    ``to_device`` (which applies the mesh sharding of
    ``serve_state_specs`` when the engine runs sharded).
  * **Eviction order** — strict LRU by last hit/capture tick, demote
    before evict; ``stats`` surfaces hits/misses/captures/demotions/
    evictions and per-tier bytes, which the engine folds into
    ``eng.stats`` under ``prefix_*`` keys.

For the EXACT fallback the snapshot is not O(1): its KV grows with the
prefix. The engine therefore switches exact configs to block-granular
paged KV (``lm.init_paged_serve_state``): a cached prefix retains its
physical pages here (refcounted in :class:`PageAllocator`) and a fork
shares every fully-covered prefix page, copying only the partial tail
page — copy-on-write at page granularity, vLLM-style. Pages only ever
append at a row's own length, so a fully-covered page is immutable and
sharing is exact, not approximate.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Optional, Sequence

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class PrefixCacheConfig:
    """Knobs for the prefix cache (engine: ``prefix_cache=``; CLI:
    ``--prefix-cache`` + budget flags).

    ``block_tokens`` is the capture/match granularity: snapshots are
    taken when a prefill cursor lands on a multiple of it. Keep it
    aligned with the engine's chunk grants (a pow-2 that divides
    ``chunk_tokens``) so capture points coincide with chunk boundaries
    and forked remainders resume on the cold-start chunk grid — that
    alignment is what makes forked streams bitwise-equal to cold-start
    ones (docs/serving.md §prefix cache). ``capture_final`` also
    snapshots completed prompts at unaligned lengths (multi-turn reuse).
    ``page_size`` / ``cache_pages`` only apply to the exact paged-KV
    layout: pool pages per block, and how many extra pool pages are
    reserved to keep cached prefixes alive beyond the slots' own needs.
    """
    block_tokens: int = 16
    device_bytes: int = 64 << 20
    host_bytes: int = 256 << 20
    capture_final: bool = True
    page_size: int = 16
    cache_pages: int = 0          # 0 -> engine default (2 slots' worth)

    def __post_init__(self):
        if self.block_tokens < 1:
            raise ValueError("block_tokens must be >= 1")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")


class NoFreePages(RuntimeError):
    """Page pool exhausted (after cache reclaim) — the engine defers
    the admission instead of corrupting resident pages."""


class PageAllocator:
    """Host-side refcounted allocator over the shared device page pool.

    Page 0 is reserved as the garbage page (masked and inactive writes
    land there) and is never handed out. ``retain`` / ``release`` move
    refcounts — a page returns to the free list when its count drops to
    zero, so cache entries and forked rows can share prefix pages and
    the pool reclaims them only when the last owner lets go.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("page pool needs >= 2 pages (page 0 is "
                             "the reserved garbage page)")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, 0, -1))
        self._ref = np.zeros(n_pages, np.int32)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise NoFreePages(
                f"need {n} pages, {len(self._free)} free "
                f"(pool has {self.n_pages})")
        ids = [self._free.pop() for _ in range(n)]
        self._ref[ids] = 1
        return ids

    def retain(self, ids: Sequence[int]) -> None:
        for i in ids:
            assert self._ref[i] > 0, f"retain of unowned page {i}"
            self._ref[i] += 1

    def release(self, ids: Sequence[int]) -> None:
        for i in ids:
            assert i != 0 and self._ref[i] > 0, f"bad release of page {i}"
            self._ref[i] -= 1
            if self._ref[i] == 0:
                self._free.append(i)


def prefix_key(tokens: Sequence[int]) -> str:
    """Stable content hash of a token prefix (int32 little-endian)."""
    return hashlib.blake2b(np.asarray(tokens, np.int32).tobytes(),
                           digest_size=16).hexdigest()


def _tree_bytes(tree) -> int:
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(tree))


class _Entry:
    __slots__ = ("key", "tokens", "state", "on_host", "state_bytes",
                 "page_bytes", "pages", "tick")

    def __init__(self, key, tokens, state, state_bytes, page_bytes,
                 pages, tick):
        self.key = key
        self.tokens = tokens            # tuple[int], len == prefix_len
        self.state = state              # 1-row detached serve state
        self.on_host = False
        self.state_bytes = state_bytes
        self.page_bytes = page_bytes    # resident KV page bytes (paged)
        self.pages = pages              # retained physical ids, or None
        self.tick = tick


class PrefixCache:
    """Two-tier LRU store of prefix-state snapshots (module docstring).

    ``to_host`` / ``to_device`` are the tier movers the engine supplies
    (``jax.device_get`` and a mesh-aware ``device_put``);
    ``release_pages`` is called with an evicted entry's retained page
    ids (paged exact only) so the :class:`PageAllocator` can reclaim
    them.
    """

    def __init__(self, cfg: PrefixCacheConfig, *,
                 to_host: Callable = jax.device_get,
                 to_device: Callable = jax.device_put,
                 release_pages: Optional[Callable] = None):
        self.cfg = cfg
        self._to_host = to_host
        self._to_device = to_device
        self._release_pages = release_pages
        self._entries: dict[str, _Entry] = {}
        self._lengths: dict[int, int] = {}   # prefix_len -> entry count
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.captures = 0
        self.demotions = 0
        self.evictions = 0

    # -- introspection ----------------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    def has(self, tokens: Sequence[int]) -> bool:
        return prefix_key(tokens) in self._entries

    @property
    def device_bytes_used(self) -> int:
        return sum(e.state_bytes + e.page_bytes
                   for e in self._entries.values() if not e.on_host)

    @property
    def host_bytes_used(self) -> int:
        return sum(e.state_bytes for e in self._entries.values()
                   if e.on_host)

    @property
    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"prefix_entries": len(self._entries),
                "prefix_hits": self.hits,
                "prefix_misses": self.misses,
                "prefix_hit_rate": self.hits / total if total else 0.0,
                "prefix_captures": self.captures,
                "prefix_demotions": self.demotions,
                "prefix_evictions": self.evictions,
                "prefix_device_bytes": self.device_bytes_used,
                "prefix_host_bytes": self.host_bytes_used}

    # -- lookup -----------------------------------------------------------

    def match(self, prompt: Sequence[int]) -> Optional[_Entry]:
        """Longest cached prefix of ``prompt`` that leaves >= 1 prompt
        token unprefilled. Verifies tokens (not just the hash), bumps
        the entry's LRU tick, and counts a hit or miss.

        Candidate lengths are exactly the prefix lengths present in the
        store (a length with no entry can never match), and all of
        their keys come out of ONE rolling blake2b pass over the
        prompt — O(len + candidates) work per lookup instead of
        rehashing every block-aligned prefix from scratch."""
        limit = len(prompt) - 1
        cands = sorted(n for n in self._lengths if n <= limit)
        if not cands:
            self.misses += 1
            return None
        buf = np.asarray(prompt[:cands[-1]], np.int32).tobytes()
        roll = hashlib.blake2b(digest_size=16)
        keys: dict[int, str] = {}
        prev = 0
        for n in cands:
            roll.update(buf[4 * prev:4 * n])
            prev = n
            keys[n] = roll.copy().hexdigest()
        for n in reversed(cands):
            ent = self._entries.get(keys[n])
            if ent is not None and ent.tokens == tuple(prompt[:n]):
                self._tick += 1
                ent.tick = self._tick
                self.hits += 1
                return ent
        self.misses += 1
        return None

    def device_state(self, ent: _Entry):
        """The entry's snapshot on device, promoting a host-tier entry
        (and re-balancing the device budget) if needed."""
        if ent.on_host:
            ent.state = self._to_device(ent.state)
            ent.on_host = False
            self._rebalance()
        return ent.state

    # -- insert / evict ---------------------------------------------------

    def put(self, tokens: Sequence[int], state, *,
            pages: Optional[list[int]] = None,
            page_bytes: int = 0) -> None:
        """Capture a snapshot for ``tokens``. ``state`` is a 1-row
        detached serve state gathered from the staging pool; ``pages``
        (exact paged only) are the physical page ids covering the
        prefix, already retained by the caller."""
        key = prefix_key(tokens)
        if key in self._entries:            # concurrent duplicate capture
            if pages is not None and self._release_pages is not None:
                self._release_pages(pages)
            return
        self._tick += 1
        ent = _Entry(key, tuple(int(t) for t in tokens), state,
                     _tree_bytes(state), page_bytes, pages, self._tick)
        self._entries[key] = ent
        n = len(ent.tokens)
        self._lengths[n] = self._lengths.get(n, 0) + 1
        self.captures += 1
        self._rebalance()

    def _drop(self, ent: _Entry) -> None:
        del self._entries[ent.key]
        n = len(ent.tokens)
        self._lengths[n] -= 1
        if not self._lengths[n]:
            del self._lengths[n]
        if ent.pages is not None and self._release_pages is not None:
            self._release_pages(ent.pages)
        self.evictions += 1

    def _rebalance(self) -> None:
        """Demote LRU device entries past ``device_bytes``, then evict
        LRU host entries past ``host_bytes``. Paged entries keep their
        KV pages resident on device either way, so their page bytes
        count against the device budget until eviction."""
        dev = [e for e in self._entries.values() if not e.on_host]
        dev.sort(key=lambda e: e.tick)
        used = sum(e.state_bytes + e.page_bytes for e in dev)
        for e in dev:
            if used <= self.cfg.device_bytes:
                break
            used -= e.state_bytes + e.page_bytes
            if e.page_bytes:
                # demoting cannot free resident pages — evict instead
                self._drop(e)
                continue
            e.state = self._to_host(e.state)
            e.on_host = True
            self.demotions += 1
        host = [e for e in self._entries.values() if e.on_host]
        host.sort(key=lambda e: e.tick)
        used = sum(e.state_bytes for e in host)
        for e in host:
            if used <= self.cfg.host_bytes:
                break
            used -= e.state_bytes
            self._drop(e)

    def reclaim_pages(self, allocator: PageAllocator, need: int, *,
                      exclude: Optional[_Entry] = None) -> bool:
        """Evict LRU paged entries until ``allocator`` has ``need``
        free pages (or no evictable paged entries remain). ``exclude``
        pins one entry — the prefix the caller is about to fork from —
        outside the eviction scan, so a reclaim can never drop the very
        pages the admission is sharing and hand them back out of the
        free list as writable growth pages. Returns success — False
        tells the engine to defer the admission (backpressure)."""
        while allocator.n_free < need:
            paged = [e for e in self._entries.values()
                     if e.pages is not None and e is not exclude]
            if not paged:
                return False
            self._drop(min(paged, key=lambda e: e.tick))
        return True

    def clear(self) -> None:
        for ent in list(self._entries.values()):
            self._drop(ent)
