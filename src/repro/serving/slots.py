"""Slot-pool pytree surgery for the continuous-batching engine.

The engine's device state is one big serve-state pytree built by
``lm.init_serve_state(cfg, b=max_slots, per_slot=True)``. Slot i of the
pool is batch row i of every leaf, but the slot axis is NOT uniform
across the tree:

  * ``state["units"]`` leaves are stacked over scanned layer units, so
    they carry a leading (n_units,) axis and the slot axis is **1**;
  * ``state["rem"]`` (unscanned remainder layers) and ``state["pos"]``
    have the slot axis at **0**;
  * scalar per-sequence leaves produced by a B=1 prefill (``pos``, the
    exact-cache ``length``) have NO slot axis and are broadcast in.

All engine mutations reduce to three primitives here — gather a slot,
scatter a (B=1) state into a slot, and a masked freeze of inactive
slots — each written once over that axis map instead of per leaf.
These run inside the engine's jitted step functions; ``idx`` and
``active`` are traced, so admission at any slot reuses one compile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def tree_slot_map(fn, pool: dict, *others: dict) -> dict:
    """Map ``fn(pool_leaf, *other_leaves, axis=slot_axis)`` over serve
    states. ``others`` must share ``pool``'s tree structure (None leaves,
    e.g. the unused half of AttnServeState, are skipped by tree_map)."""
    out = {}
    if "units" in pool:
        out["units"] = jax.tree_util.tree_map(
            lambda p, *o: fn(p, *o, axis=1), pool["units"],
            *[t["units"] for t in others])
    if "rem" in pool:
        out["rem"] = jax.tree_util.tree_map(
            lambda p, *o: fn(p, *o, axis=0), pool["rem"],
            *[t["rem"] for t in others])
    out["pos"] = fn(pool["pos"], *[t["pos"] for t in others], axis=0)
    return out


def write_slot(pool: dict, new: dict, idx: Array) -> dict:
    """Scatter a single-sequence serve state into slot ``idx``.

    ``new`` is the state returned by a B=1 ``lm.prefill`` (or a B=1
    decode chain): its batch axis has size 1 where present, and its
    per-sequence scalars (``pos``, exact ``length``) have one dim less
    than the pool leaf — those are unsqueezed at the slot axis first.
    """
    def _write(p, n, axis):
        n = jnp.asarray(n)
        if n.ndim < p.ndim:
            n = jnp.expand_dims(n, axis)
        return jax.lax.dynamic_update_slice_in_dim(
            p, n.astype(p.dtype), idx, axis=axis)
    return tree_slot_map(_write, pool, new)


def read_slot(pool: dict, idx: Array) -> dict:
    """Gather slot ``idx`` back out as a B=1 serve state (keeps the
    size-1 slot axis so the result round-trips through write_slot)."""
    def _read(p, axis):
        return jax.lax.dynamic_slice_in_dim(p, idx, 1, axis=axis)
    return tree_slot_map(_read, pool)


def freeze_inactive(pool_old: dict, pool_new: dict, active: Array) -> dict:
    """Keep ``pool_new`` where ``active`` (bool (S,)), else ``pool_old``.

    Decode always advances all S slots in lock-step; this masks the
    write-back so evicted/empty slots stay bit-frozen instead of
    accumulating garbage (and so the exact-cache write index of a free
    slot cannot run past the end of its page).
    """
    def _sel(old, new, axis):
        shape = [1] * old.ndim
        shape[axis] = active.shape[0]
        return jnp.where(active.reshape(shape), new, old)
    return tree_slot_map(lambda o, n, axis: _sel(o, n, axis),
                         pool_old, pool_new)
