"""Slot-layout module: pytree surgery for the engine's device pools.

The engine keeps TWO device-resident pools, both built by
``lm.init_serve_state(cfg, b=max_slots, per_slot=True)``:

  * the **slot pool** — one serve-state row per decoding sequence; and
  * the **staging pool** — a fixed-size pool of mid-prefill rows (one
    per staged admission, indexed by its reserved slot), replacing the
    old per-slot host-held B=1 staging states. Keeping staged rows in
    one pool is what lets a batched multi-admission prefill gather P
    rows, advance them in ONE padded (P, L) ``prefill_chunk`` call, and
    scatter them back.

Slot i of a pool is batch row i of every leaf, but the slot axis is NOT
uniform across the tree:

  * ``state["units"]`` leaves are stacked over scanned layer units, and
    ``state["layers"]`` leaves (the layer-stacked layout of homogeneous
    configs, ``lm.init_serve_state(stacked=True)``) over ALL layers —
    both carry a leading layer axis and the slot axis is **1**;
  * ``state["rem"]`` (unscanned remainder layers) and ``state["pos"]``
    have the slot axis at **0**;
  * scalar per-sequence leaves produced by a B=1 prefill (``pos``, the
    exact-cache ``length``) have NO slot axis and are broadcast in.

All engine mutations reduce to the primitives here — multi-index
gather/scatter (``read_slots`` / ``write_slots``), their single-slot
forms, the one-row broadcast scatter that seeds admissions and forks
cached prefixes (``fork_slots``), the fused staging-to-pool commit
(``merge_slots``), and a masked freeze of inactive slots — each
written once over that axis map instead of per leaf. ``PackBuffer`` is
the host-side counterpart: the double-buffered token staging the
overlapped engine packs the NEXT prefill chunk into while the current
one is in flight. These run inside
the engine's jitted step functions; ``idx`` and ``active`` are traced,
so admission at any slot reuses one compile (one executable per
distinct index-vector LENGTH for the multi-index forms).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def tree_slot_map(fn, pool: dict, *others: dict) -> dict:
    """Map ``fn(pool_leaf, *other_leaves, axis=slot_axis)`` over serve
    states. ``others`` must share ``pool``'s tree structure (None leaves,
    e.g. the unused half of AttnServeState, are skipped by tree_map)."""
    out = {}
    for lk in ("units", "layers"):         # leading layer axis -> slot @ 1
        if lk in pool:
            out[lk] = jax.tree_util.tree_map(
                lambda p, *o: fn(p, *o, axis=1), pool[lk],
                *[t[lk] for t in others])
    if "rem" in pool:
        out["rem"] = jax.tree_util.tree_map(
            lambda p, *o: fn(p, *o, axis=0), pool["rem"],
            *[t["rem"] for t in others])
    out["pos"] = fn(pool["pos"], *[t["pos"] for t in others], axis=0)
    return out


def write_slots(pool: dict, new: dict, idx: Array) -> dict:
    """Scatter a P-row serve state into slots ``idx`` ((P,) int32).

    ``new`` must be a per-slot state whose slot axis has size P at the
    same position as ``pool``'s (e.g. the result of :func:`read_slots`,
    or a batched ``prefill_chunk`` advance of one). Rows land at
    ``pool[..., idx[p], ...] = new[..., p, ...]``; duplicate indices
    follow XLA scatter semantics (last write wins) — the engine never
    produces them.
    """
    def _write(p, n, axis):
        n = jnp.asarray(n).astype(p.dtype)
        # scatter at the slot axis directly — no moveaxis, which would
        # materialize a transposed copy of the whole pool per call
        ix = (slice(None),) * axis + (idx,)
        return p.at[ix].set(n)
    return tree_slot_map(_write, pool, new)


def read_slots(pool: dict, idx: Array) -> dict:
    """Gather slots ``idx`` ((P,) int32) as a P-row per-slot serve state
    (slot axis kept, so the result round-trips through write_slots)."""
    def _read(p, axis):
        return jnp.take(p, idx, axis=axis)
    return tree_slot_map(_read, pool)


def write_slot(pool: dict, new: dict, idx: Array) -> dict:
    """Scatter a single-sequence serve state into slot ``idx`` (() int32).

    ``new`` is the state returned by a B=1 ``lm.prefill`` (or a B=1
    decode chain): its batch axis has size 1 where present, and its
    per-sequence scalars (``pos``, exact ``length``) have one dim less
    than the pool leaf — those are unsqueezed at the slot axis first.
    Thin wrapper over :func:`write_slots` with a length-1 index vector.
    """
    def _expand(p, n, axis):
        n = jnp.asarray(n)
        return jnp.expand_dims(n, axis) if n.ndim < p.ndim else n
    return write_slots(pool, tree_slot_map(_expand, pool, new),
                       jnp.asarray(idx, jnp.int32)[None])


def read_slot(pool: dict, idx: Array) -> dict:
    """Gather slot ``idx`` (() int32) back out as a B=1 serve state
    (keeps the size-1 slot axis so the result round-trips through
    write_slot). Thin wrapper over :func:`read_slots` with a length-1
    index vector."""
    return read_slots(pool, jnp.asarray(idx, jnp.int32)[None])


def fork_slots(pool: dict, row: dict, idx: Array) -> dict:
    """Broadcast a ONE-row serve state into slots ``idx`` ((P,) int32).

    The fork-on-admit scatter of the prefix cache: every admitted slot's
    staging row is seeded from the same snapshot — a cached prefix state
    or the engine's fresh-row template — in one scatter. For PRF kinds
    the row is the fixed-size (S, z, c) tuple, so forking a prefix into
    P requests is O(P · state) regardless of how long the prefix is.
    """
    k = idx.shape[0]
    rows = tree_slot_map(lambda p, axis: jnp.repeat(p, k, axis=axis), row)
    return write_slots(pool, rows, idx)


def merge_slots(dst: dict, src: dict, idx: Array) -> dict:
    """Copy rows ``idx`` ((P,) int32) of ``src`` into the same rows of
    ``dst`` — the commit scatter that promotes finished staging-pool
    rows into the slot pool. One tree traversal: each leaf is a gather
    at the slot axis fused with a scatter at the same indices (the
    separate ``read_slots`` + ``write_slots`` pair would walk the tree
    twice and materialize the gathered sub-state between the jit-traced
    calls). Under the overlapped step loop this is the *deferred merge*:
    it is dispatched at the START of the step after the prefill chunk
    landed, ahead of that step's decode, so decode never waits on an
    in-flight prefill (repro/serving/engine.py)."""
    def _merge(d, s, axis):
        ix = (slice(None),) * axis + (idx,)
        return d.at[ix].set(jnp.take(s, idx, axis=axis).astype(d.dtype))
    return tree_slot_map(_merge, dst, src)


class PackBuffer:
    """Double-buffered host staging for packed prefill-chunk tokens.

    The overlapped engine packs prompt chunk N+1 on the host while chunk
    N's dispatch (and its host-to-device copy) is still in flight. Two
    preallocated ``(max_rows, max_chunk)`` int32 buffers alternate:
    ``pack()`` fills the idle buffer and returns a ``(P, l_pad)`` view
    of it, so the view handed to chunk N's ``jnp.asarray`` is never the
    buffer being overwritten for chunk N+1. (On CPU the copy is
    synchronous and this is belt-and-braces; on accelerators with async
    host-to-device transfer the flip is what makes in-place repacking
    safe.) Rows are zero-padded to ``l_pad``; ragged rows carry their
    real lengths separately (``valid_len`` in the engine)."""

    def __init__(self, max_rows: int, max_chunk: int):
        self._bufs = [np.zeros((max_rows, max_chunk), np.int32)
                      for _ in range(2)]
        self._flip = 0

    def pack(self, rows: list, l_pad: int) -> np.ndarray:
        """Fill the idle buffer with ``rows`` (sequences of ints, each
        <= l_pad) zero-padded to ``l_pad`` and return the (P, l_pad)
        view. Flips buffers on every call."""
        buf = self._bufs[self._flip]
        self._flip ^= 1
        view = buf[:len(rows), :l_pad]
        view[:] = 0
        for r, toks in enumerate(rows):
            view[r, :len(toks)] = toks
        return view


def freeze_inactive(pool_old: dict, pool_new: dict, active: Array,
                    all_active: bool = False) -> dict:
    """Keep ``pool_new`` where ``active`` (bool (S,)), else ``pool_old``.

    Decode always advances all S slots in lock-step; this masks the
    write-back so evicted/empty slots stay bit-frozen instead of
    accumulating garbage (and so the exact-cache write index of a free
    slot cannot run past the end of its page).

    ``all_active`` is a STATIC fast path: when the caller knows on the
    host that every slot is live (a fully-occupied decode step — the
    common case under load), the pool-wide select is the identity and is
    skipped entirely. The result is bit-identical either way.
    """
    if all_active:
        return pool_new

    def _sel(old, new, axis):
        shape = [1] * old.ndim
        shape[axis] = active.shape[0]
        return jnp.where(active.reshape(shape), new, old)
    return tree_slot_map(lambda o, n, axis: _sel(o, n, axis),
                         pool_old, pool_new)
