"""Continuous-batching serving engine over the O(1)-state PRF decode.

The paper's serving claim (docs/serving.md) is that PRF attention decodes
from a fixed-size running state — an (m x d_v) sum S, an (m,) normalizer
z and the running stabilizer max c per head — so a server can multiplex
many users over one batched decode step regardless of how long each
context is. This engine is that multiplexer:

  * a FIFO **request queue** with arrival times (Poisson traffic plugs in
    here — see benchmarks/serve_latency.py);
  * a device-resident **slot pool**: one serve-state pytree with
    ``max_slots`` batch rows, per-slot positions and (for the exact
    fallback) per-slot KV write indices (repro/serving/slots.py);
  * a **scheduler** that admits a queued request into any free slot by
    prefilling it as a B=1 sequence and scattering the resulting state
    into the pool, and evicts a slot the moment its sequence finishes —
    both mid-decode, without touching other slots;
  * one jitted **batched decode step** that advances all slots in
    lock-step; inactive slots are masked so their state stays bit-frozen.

Numerical contract: slot rows are computed elementwise over the batch
axis, so a sequence decoded inside a busy heterogeneous batch produces
bit-identical f32 logits to the same sequence decoded alone with
``lm.prefill`` + ``lm.decode_step`` (tests/test_serving_engine.py
asserts this for darkformer, performer and exact kernels).

Prefill compiles once per distinct prompt length. Setting
``prefill_bucket=N`` caps that at one compile per multiple of N: the
prompt head (largest multiple of N) is prefills and the remaining tail
tokens are fed through the single-sequence decode path before the state
is scattered into the pool. Bucketed admission changes the k-stabilizer
trajectory (a running max instead of one whole-prompt max), so outputs
match the unbucketed path only up to f32 rounding — leave it off when
bit-exactness matters more than compile count.
"""
from __future__ import annotations

import bisect
import time
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving import slots as slot_ops
from repro.serving.request import Request, RequestResult

Array = jax.Array


class _Slot:
    """Host-side record of the sequence occupying one pool row."""

    __slots__ = ("req", "result", "budget")

    def __init__(self, req: Request, result: RequestResult, budget: int):
        self.req = req
        self.result = result
        self.budget = budget


class ServingEngine:
    """Continuous-batching generation over a fixed slot pool.

    Typical use::

        eng = ServingEngine(params, cfg, max_slots=8, max_len=512)
        eng.submit(Request(prompt=[...], max_new_tokens=64))
        results = eng.run()

    or drive it step-by-step (one batched decode per ``step()``) and
    ``submit`` more requests while others are mid-decode.
    """

    def __init__(self, params, cfg: lm.ModelConfig, *, max_slots: int = 4,
                 max_len: int = 256, prefill_bucket: Optional[int] = None,
                 seed: int = 0):
        if cfg.modality != "text":
            raise ValueError("serving engine drives text decode only")
        if prefill_bucket is not None and prefill_bucket < 1:
            raise ValueError("prefill_bucket must be >= 1")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket
        self.pool = lm.init_serve_state(cfg, b=max_slots, max_len=max_len,
                                        per_slot=True)

        self._slots: list[Optional[_Slot]] = [None] * max_slots
        self._active = np.zeros(max_slots, bool)
        self._temps = np.zeros(max_slots, np.float32)
        self._toks = np.zeros(max_slots, np.int32)
        self._queue: list[Request] = []        # sorted by arrival_time
        self._key = jax.random.PRNGKey(seed)
        self._step_count = 0
        self._t0: Optional[float] = None
        self._stats = {"decode_steps": 0, "decode_slot_steps": 0,
                       "prefill_tokens": 0, "emitted_tokens": 0,
                       "admitted": 0, "finished": 0}

        cfg_ = cfg  # closed over by the jitted steps

        def _decode(params, pool, toks, active):
            logits, new = lm.decode_step(params, cfg_, toks, pool)
            return logits, slot_ops.freeze_inactive(pool, new, active)

        def _prefill(params, tokens):
            logits, st = lm.prefill(params, cfg_, {"tokens": tokens},
                                    max_len=max_len)
            return logits[:, -1], st           # (1, V), state

        def _decode_b1(params, tok, st):
            return lm.decode_step(params, cfg_, tok, st)

        def _write(pool, st, idx):
            return slot_ops.write_slot(pool, st, idx)

        def _sample(key, logits, temps):
            greedy = jnp.argmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
            drawn = jax.random.categorical(key, scaled, axis=-1)
            return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)

        self._decode_fn = jax.jit(_decode, donate_argnums=(1,))
        self._decode_b1_fn = jax.jit(_decode_b1)
        self._write_fn = jax.jit(_write, donate_argnums=(0,))
        self._sample_fn = jax.jit(_sample)
        # one jit wrapper; XLA caches one executable per prompt length
        # (prefill_bucket caps the number of distinct lengths)
        self._prefill_fn = jax.jit(_prefill)

    # -- clock ------------------------------------------------------------

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return time.monotonic() - self._t0

    # -- client API -------------------------------------------------------

    def submit(self, req: Union[Request, Sequence[int]], **kw) -> int:
        """Queue a request (or a bare token prompt). Returns its uid."""
        if not isinstance(req, Request):
            req = Request(prompt=list(req), **kw)
        if len(req.prompt) == 0:
            raise ValueError("empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} >= max_len {self.max_len}")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (admission "
                             "always samples the first token)")
        bisect.insort(self._queue, req, key=lambda r: r.arrival_time)
        return req.uid

    def cancel(self, uid: int) -> Optional[RequestResult]:
        """Evict a queued or mid-decode request. Returns its partial
        result (None if the uid is unknown)."""
        for i, req in enumerate(self._queue):
            if req.uid == uid:
                self._queue.pop(i)
                return RequestResult(uid=uid, prompt=list(req.prompt),
                                     arrival_time=req.arrival_time,
                                     cancelled=True)
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.req.uid == uid:
                res = slot.result
                res.cancelled = True
                res.finish_time = self._now()
                self._free(i)
                return res
        return None

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or self.num_active > 0

    def next_arrival(self) -> Optional[float]:
        return self._queue[0].arrival_time if self._queue else None

    # -- scheduler --------------------------------------------------------

    def _free(self, i: int) -> None:
        self._slots[i] = None
        self._active[i] = False
        self._temps[i] = 0.0

    def _sample_one(self, req: Request, logits_row: Array) -> int:
        key = jax.random.fold_in(
            jax.random.fold_in(self._key, req.uid), self._step_count)
        temps = jnp.full((1,), req.temperature, jnp.float32)
        return int(self._sample_fn(key, logits_row, temps)[0])

    def _admit(self, req: Request, slot: int) -> None:
        prompt = np.asarray(req.prompt, np.int32)
        length = len(prompt)
        if self.prefill_bucket and length > self.prefill_bucket:
            head = (length // self.prefill_bucket) * self.prefill_bucket
        else:
            head = length
        logits, st = self._prefill_fn(
            self.params, jnp.asarray(prompt[None, :head]))
        for j in range(head, length):          # decode-tail admission
            tok = jnp.asarray(prompt[j:j + 1])
            logits, st = self._decode_b1_fn(self.params, tok, st)
        self.pool = self._write_fn(self.pool, st, jnp.int32(slot))

        first = self._sample_one(req, logits)
        now = self._now()
        result = RequestResult(uid=req.uid, prompt=list(map(int, prompt)),
                               tokens=[first],
                               arrival_time=req.arrival_time,
                               admit_time=now, token_times=[now])
        # exact-cache pages hold max_len keys: prompt + decoded tokens
        budget = min(req.max_new_tokens, self.max_len - length)
        self._slots[slot] = _Slot(req, result, budget)
        self._active[slot] = True
        self._temps[slot] = req.temperature
        self._toks[slot] = first
        self._stats["prefill_tokens"] += length
        self._stats["emitted_tokens"] += 1
        self._stats["admitted"] += 1

    def _admissions(self, now: float) -> None:
        while self._queue and self._queue[0].arrival_time <= now:
            free = [i for i in range(self.max_slots)
                    if self._slots[i] is None]
            if not free:
                return
            self._admit(self._queue.pop(0), free[0])

    # -- decode -----------------------------------------------------------

    def step(self) -> list[RequestResult]:
        """Admit what has arrived, run one batched decode step over the
        active slots, evict finished sequences. Returns newly finished
        results (possibly empty)."""
        finished: list[RequestResult] = []
        self._admissions(self._now())
        # admission may already exhaust a request (budget/eos on token 1)
        for i, slot in enumerate(self._slots):
            if slot is not None and self._done(slot):
                finished.append(self._finish(i))
        if not self._active.any():
            return finished

        self._step_count += 1
        logits, self.pool = self._decode_fn(
            self.params, self.pool, jnp.asarray(self._toks),
            jnp.asarray(self._active))
        key = jax.random.fold_in(self._key, self._step_count)
        toks = np.asarray(self._sample_fn(key, logits,
                                          jnp.asarray(self._temps)))
        now = self._now()
        n_act = int(self._active.sum())
        self._stats["decode_steps"] += 1
        self._stats["decode_slot_steps"] += n_act
        for i in np.nonzero(self._active)[0]:
            slot = self._slots[i]
            tok = int(toks[i])
            slot.result.tokens.append(tok)
            slot.result.token_times.append(now)
            self._toks[i] = tok
            self._stats["emitted_tokens"] += 1
            if self._done(slot):
                finished.append(self._finish(i))
        return finished

    def _done(self, slot: _Slot) -> bool:
        toks = slot.result.tokens
        if len(toks) >= slot.budget:
            return True
        return slot.req.eos_id is not None and toks[-1] == slot.req.eos_id

    def _finish(self, i: int) -> RequestResult:
        res = self._slots[i].result
        res.finish_time = self._now()
        self._free(i)
        self._stats["finished"] += 1
        return res

    # -- batch runner -----------------------------------------------------

    def run(self, realtime: bool = False) -> list[RequestResult]:
        """Drive ``step()`` until queue and slots drain.

        ``realtime=True`` honors future ``arrival_time``s by sleeping
        while the pool is empty (Poisson-traffic benchmarking); otherwise
        arrival order is respected but waits are skipped.
        """
        results: list[RequestResult] = []
        while self.has_work:
            if self.num_active == 0 and self._queue:
                wait = self._queue[0].arrival_time - self._now()
                if wait > 0:
                    if realtime:
                        time.sleep(wait)
                    else:
                        self._t0 -= wait       # jump the clock forward
            results.extend(self.step())
        return results

    # -- metrics ----------------------------------------------------------

    @property
    def stats(self) -> dict:
        s = dict(self._stats)
        steps = max(s["decode_steps"], 1)
        # fraction of slot-steps that carried a live sequence
        s["mean_occupancy"] = (s["decode_slot_steps"]
                               / (steps * self.max_slots))
        return s
