"""Continuous-batching serving engine over the O(1)-state PRF decode.

The paper's serving claim (docs/serving.md) is that PRF attention decodes
from a fixed-size running state — an (m x d_v) sum S, an (m,) normalizer
z and the running stabilizer max c per head — so a server can multiplex
many users over one batched decode step regardless of how long each
context is. The same state is what makes prefill *chunkable*: the state
after k prompt tokens is a valid resume point (``lm.prefill_chunk``), so
prompt work can be cut into budgeted slices instead of monopolizing the
device. This engine is that multiplexer:

  * a FIFO **request queue** with arrival times (Poisson traffic plugs in
    here — see benchmarks/serve_latency.py);
  * a device-resident **slot pool**: one serve-state pytree with
    ``max_slots`` batch rows, per-slot positions and (for the exact
    fallback) per-slot KV write indices — plus a same-shape **staging
    pool** holding every mid-prefill admission's partial state
    (repro/serving/slots.py);
  * a **token-budget packer**: each ``step()`` splits at most
    ``chunk_tokens`` prompt tokens across ALL staged admissions and
    advances them together in ONE padded (P, L) ``prefill_chunk`` call
    — under bucketing the grants are COALESCED to one shared pow-2
    size (prev_pow2(budget/P)) so non-tail rows pack with zero padding
    waste (occupancy 1.0 under ragged bursts); ragged rows are masked
    per-row (``valid_len``) and chunk lengths are bucketed to powers
    of two so compiles stay bounded by (rows <= max_slots) x (log2
    length buckets). With ``cfg.use_kernel`` the packed call runs the
    ``prf_fused_prefill`` megakernel against the same engine-built
    projections as decode (one pallas_call per layer per chunk,
    valid_len masked in-kernel, staging rows aliased in place).
    ``chunk_tokens=None`` is the blocking baseline: all staged
    admissions prefill their whole prompts in one padded call;
  * one jitted **batched decode step** that advances all slots in
    lock-step; inactive slots are masked so their state stays bit-frozen
    (skipped entirely — a static fast path — when every slot is live).
    A mid-prefill slot's state lives in the staging pool until its last
    chunk lands, so partial prefills never perturb pool rows. For
    homogeneous configs both pools are LAYER-STACKED
    (``lm.can_stack_layers``): the step scans one compiled layer body
    over a leading (n_layers,) axis, and with ``cfg.use_kernel`` that
    body runs the ``prf_fused_decode`` megakernel against per-layer
    projections precomposed once at engine build
    (``lm.build_decode_proj``).

Two step schedulers share those pieces:

**Sequential** (``overlap=False``): one packed prefill chunk, then one
batched decode, back-to-back with a blocking token readback — the
reference scheduler every numerical-contract test pins down.

**Overlapped** (``overlap=True``, the serve-CLI default): the step loop
is restructured around JAX async dispatch so decode never waits on
prefill and the host never idles on readback:

  1. *retire* — block on the ONE-STEP-DELAYED sample buffer from the
     previous step's decode (``jax.device_get`` on tokens that have had
     a whole prefill chunk's worth of device time to finish), append
     the now-ready tokens, fire ``Request.on_token`` hooks, evict
     finished rows. This is the step's only synchronization point; the
     blocked time is recorded per step as ``decode_stall_ms``;
  2. *admit* — reserve slots + batched staging-row reset, as before;
  3. *merge* — admissions whose final prefill chunk landed during the
     PREVIOUS step are committed into the slot pool now (one deferred
     ``merge_slots`` scatter), their first tokens sampled from the
     saved final-chunk logits and scattered into the device-resident
     token feed — so the merge rides ahead of this step's decode
     instead of serializing after a prefill;
  4. *decode dispatch* — the batched decode + sample step is enqueued
     immediately, reading last step's sampled tokens straight from the
     device feed buffer (no host round-trip on the token feedback
     path); its sampled tokens become the NEXT step's retire target;
  5. *prefill dispatch* — the chunk PACKED during the previous step is
     enqueued behind the decode (rows whose request was cancelled since
     packing are dropped); admissions finishing their prompt this chunk
     queue a pending merge for step +1;
  6. *pack* — the NEXT chunk's token block is packed on the host into a
     double-buffered staging array (``slots.PackBuffer``) while this
     step's chunk is still in flight.

The pipeline trades one step of latency on each edge (admission to
first chunk, prefill completion to decode participation, sample to host
visibility) for a decode dispatch that never blocks on prefill or
readback: all host-side packing, bookkeeping and sampling-parameter
work overlaps device execution, and the decode stall observed at retire
collapses to whatever dispatch could not hide. ``flush()`` drains the
in-flight tail (stream end / step-driven callers); cancellation drops a
request's in-flight tokens without a callback.

``prefix_cache=`` adds admission-time prefix reuse
(repro/serving/prefix_cache.py): chunked prefill captures state
snapshots at block-aligned cursor boundaries, and a later request whose
prompt starts with a cached prefix is admitted by FORKING the snapshot
— one broadcast scatter seeds its staging row (``slots.fork_slots``)
and its cursor starts at the cached length, so only the un-cached
suffix is prefilled. For the PRF kinds the fork is O(1) in prefix
length (the state is the fixed-size (S, z, c) tuple); exact configs
switch the pools to a block-granular PAGED KV layout — rows hold page
tables over shared page pools, a fork shares the prefix's full pages
(refcounted) and copies only the partial tail page (copy-on-write).
Both schedulers go through the same admission path, so fork-on-admit
composes with overlap, cancel and flush; ``stats`` gains ``prefix_*``
hit/capture/eviction counters and ``forked_tokens``.

Pass ``mesh=`` to place BOTH pools under a device mesh: every pool leaf
is sharded per ``repro.parallel.serve_state_specs`` (slots over the data
axes, head groups of the KV-cache / linear state over 'model'),
``device_put`` at construction, donated through every step, and pinned
with ``with_sharding_constraint`` inside the jitted step functions so
XLA never silently migrates the pool. Decode under a mesh is
token-identical to the unsharded engine (tests/test_distributed.py,
tests/test_overlapped_serving.py).

Numerical contract: slot rows are computed elementwise over the batch
axis, so a sequence decoded inside a busy heterogeneous batch produces
bit-identical f32 logits to the same sequence decoded alone with
``lm.prefill`` + ``lm.decode_step`` (tests/test_serving_engine.py
asserts this for darkformer, performer and exact kernels). Chunking a
prompt changes the k-stabilizer trajectory (a running max instead of one
whole-prompt max), so chunked admission matches blocking admission to
f32 rounding — and bit-exactly when ``chunk_tokens >= prompt_len``
(tests/test_chunked_prefill.py). Batching staged admissions into one
padded call masks every padded position out of the advanced states, so
batched prefill matches the serial (``prefill_rows=1``) schedule to f32
rounding; with one staged row and ``bucket_prefill=False`` the packed
call IS the legacy unpadded chunk, bit-for-bit. The overlapped loop
runs the SAME jitted step functions in a different dispatch order, so
overlap-vs-sequential token streams are identical per request
(tests/test_overlapped_serving.py asserts bitwise stream equality under
Poisson admission storms, including mid-stream cancel and eviction).

Sampling: per-request ``temperature`` / ``top_k`` / ``top_p`` are
applied inside one jitted batched sample step; the defaults (0 / 0 /
1.0) leave the greedy path bit-identical to plain argmax. Every row
draws with its own key ``fold_in(fold_in(base, uid), token_index)`` —
a schedule-invariant derivation (independent of step count, batch
composition, and chunk boundaries), which is what lets sampled streams
match bitwise across the sequential and overlapped schedulers.

Timing contract: every recorded token time is a *readiness* time — the
engine blocks on the device value before reading the clock, never
timing a dispatch return (under async dispatch a ``perf_counter`` delta
around an unblocked call measures enqueue latency and silently
under-reports TPOT). ``stats`` surfaces the per-step blocked time
(``decode_stall_ms_*``) and how many dispatches the device queue ran
ahead of the fetched buffer (``dispatch_depth_*``).
"""
from __future__ import annotations

import bisect
import time
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving import slots as slot_ops
from repro.serving.prefix_cache import (NoFreePages, PageAllocator,
                                        PrefixCache, PrefixCacheConfig)
from repro.serving.request import Request, RequestResult

Array = jax.Array


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


class _Slot:
    """Host-side record of the sequence occupying one pool row.

    A slot is *prefilling* while ``cursor < len(req.prompt)`` — its
    attention state lives in staging-pool row i and it takes no part in
    decode. Once the last chunk lands the staged row is committed into
    the pool and the slot decodes. ``emitted`` counts tokens *enqueued*
    for the row (under the overlapped loop this runs one step ahead of
    ``result.tokens``, which only holds host-retired tokens); it is the
    per-row token index folded into the sampling key.
    """

    __slots__ = ("req", "result", "budget", "cursor", "emitted")

    def __init__(self, req: Request, result: RequestResult, budget: int):
        self.req = req
        self.result = result
        self.budget = budget
        self.cursor = 0
        self.emitted = 0


class ServingEngine:
    """Continuous-batching generation over a fixed slot pool.

    Typical use::

        eng = ServingEngine(params, cfg, max_slots=8, max_len=512,
                            chunk_tokens=64, overlap=True)
        eng.submit(Request(prompt=[...], max_new_tokens=64))
        results = eng.run()

    or drive it step-by-step and ``submit`` more requests while others
    are mid-decode. ``overlap=True`` selects the pipelined step loop
    (concurrent prefill/decode dispatch, double-buffered chunk packing,
    one-step-delayed non-blocking token readback — module docstring);
    the default ``overlap=False`` is the sequential reference scheduler
    (one packed prefill chunk then one blocking batched decode per
    ``step()``). Token streams are identical between the two; the
    serve CLI defaults to overlap.

    ``prefill_rows`` caps how many staged admissions share the packed
    prefill call (None = all staged, i.e. up to ``max_slots``; 1 =
    the serial one-admission-per-step schedule of the pre-batching
    engine). ``bucket_prefill`` pads packed chunk lengths up to powers
    of two to bound recompiles; disable it for bit-exact parity with
    the serial unpadded schedule at P=1. ``mesh`` shards the slot and
    staging pools per ``serve_state_specs`` (see module docstring).
    ``prefix_cache`` (True for defaults, or a ``PrefixCacheConfig``)
    enables snapshot capture + fork-on-admit prefix reuse, switching
    exact configs to the paged-KV layout (module docstring).
    """

    def __init__(self, params, cfg: lm.ModelConfig, *, max_slots: int = 4,
                 max_len: int = 256, chunk_tokens: Optional[int] = None,
                 seed: int = 0, mesh=None,
                 prefill_rows: Optional[int] = None,
                 bucket_prefill: bool = True,
                 overlap: bool = False,
                 prefix_cache: Union[bool, PrefixCacheConfig,
                                     None] = None):
        if cfg.modality != "text":
            raise ValueError("serving engine drives text decode only")
        if chunk_tokens is not None and chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1")
        if prefill_rows is not None and prefill_rows < 1:
            raise ValueError("prefill_rows must be >= 1 (None = no cap)")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.chunk_tokens = chunk_tokens
        self.prefill_rows = prefill_rows
        self.bucket_prefill = bucket_prefill
        self.overlap = overlap
        self.mesh = mesh
        # homogeneous configs stack all L layer states along one leading
        # axis so the jitted steps scan ONE compiled layer body
        # (lm.can_stack_layers); heterogeneous patterns keep the
        # per-unit layout
        self._stacked = lm.can_stack_layers(cfg)
        if prefix_cache is True:
            prefix_cache = PrefixCacheConfig()
        self._pc_cfg: Optional[PrefixCacheConfig] = prefix_cache or None
        # snapshots are captured only when a prefill cursor lands
        # EXACTLY on a block_tokens multiple — with a block_tokens that
        # does not divide chunk_tokens the chunk grants can step over
        # every boundary, silently capturing nothing (no hits, and the
        # documented fork-parity guarantee assumes the alignment), so
        # reject the combination instead of relying on the docstring
        # convention
        if (self._pc_cfg is not None and chunk_tokens is not None
                and chunk_tokens % self._pc_cfg.block_tokens):
            raise ValueError(
                f"prefix-cache block_tokens={self._pc_cfg.block_tokens}"
                f" must divide chunk_tokens={chunk_tokens}: capture "
                "points fire only when a prefill cursor lands on a "
                "block boundary (docs/serving.md §prefix cache)")
        # with a prefix cache, exact configs switch the pools to the
        # block-granular paged-KV layout: rows hold page TABLES over a
        # shared page pool, so a cached prefix's pages can be shared
        # across forks (copy-on-write on the partial tail page only).
        # Every other kind's state is fixed-size, so snapshots fork
        # through the plain broadcast scatter and need no paging.
        self._paged = (self._pc_cfg is not None and self._stacked
                       and cfg.attn.kind == "exact"
                       and any(k in ("attn", "local")
                               for k in cfg.layer_kinds()))
        if self._paged:
            ps = self._pc_cfg.page_size
            self._page_size = ps
            self._max_pages = -(-max_len // ps)
            # page 0 is the reserved garbage page; beyond every slot's
            # worst case, ``cache_pages`` extra pages let cached
            # prefixes stay resident while all slots are busy
            cache_pages = self._pc_cfg.cache_pages or 2 * self._max_pages
            n_pages = 1 + max_slots * self._max_pages + cache_pages
            self.pool = lm.init_paged_serve_state(cfg, b=max_slots,
                                                  max_len=max_len,
                                                  page_size=ps)
            self.staging = lm.init_paged_serve_state(cfg, b=max_slots,
                                                     max_len=max_len,
                                                     page_size=ps)
            self._fresh_row = lm.init_paged_serve_state(cfg, b=1,
                                                        max_len=max_len,
                                                        page_size=ps)
            self._pages = lm.init_kv_pages(cfg, n_pages, ps)
            self._alloc = PageAllocator(n_pages)
            self._page_bytes_each = (2 * cfg.n_layers * ps * cfg.n_kv
                                     * cfg.head_dim * 4)
        else:
            self.pool = lm.init_serve_state(cfg, b=max_slots,
                                            max_len=max_len,
                                            per_slot=True,
                                            stacked=self._stacked)
            # fixed-size staging pool: row i holds the partial prefill
            # state of the admission reserved on slot i (same pytree as
            # the pool)
            self.staging = lm.init_serve_state(cfg, b=max_slots,
                                               max_len=max_len,
                                               per_slot=True,
                                               stacked=self._stacked)
            # immutable one-row template scattered at admission; every
            # prefill chain starts from this fresh per-slot row
            self._fresh_row = lm.init_serve_state(cfg, b=1,
                                                  max_len=max_len,
                                                  per_slot=True,
                                                  stacked=self._stacked)
            self._pages = None
            self._alloc = None
        # physical page ids owned by each slot (refcounts in _alloc);
        # freed slots park here until _flush_freed zeroes their tables
        # and releases the pages (zombie-write safety, see _free)
        self._slot_pages: list[Optional[list[int]]] = [None] * max_slots
        self._pending_clear: list[int] = []
        # precomposed per-layer serve projections (A = (W M)^T): the
        # M·Wᵀ composition happens HERE, once at engine build — the
        # fused decode megakernel then does a single x @ A per token,
        # and the SAME pytree feeds the packed-prefill step so batched
        # ragged admission runs the fused prefill megakernel too
        self._decode_proj = lm.build_decode_proj(params, cfg,
                                                 stacked=self._stacked)
        # which implementation the jitted steps compiled — surfaced in
        # ``stats`` so bench runs can assert they measured the path
        # they claim (fused_kernel / jnp / exact / none)
        self._serve_paths = self._resolve_serve_paths()
        # likewise the layer-stacked param tree: interleaved once here
        # (a no-copy alias for the k=1 patterns) so the jitted steps
        # never re-stack weights per token
        self._step_params = params
        if self._stacked:
            self._step_params = dict(params)
            self._step_params["layers"] = lm.stack_layer_params(params,
                                                                cfg)

        pool_shardings = None
        if mesh is not None:
            from repro.parallel import serve_state_specs, make_shardings
            pool_shardings = make_shardings(
                serve_state_specs(self.pool, mesh), mesh)
            self.pool = jax.device_put(self.pool, pool_shardings)
            self.staging = jax.device_put(self.staging, pool_shardings)
            if self._paged:
                # the shared page pools carry no slot axis; replicate
                # them (page gathers/scatters are id-indexed)
                from jax.sharding import NamedSharding, PartitionSpec
                rep = NamedSharding(mesh, PartitionSpec())
                self._pages = jax.device_put(self._pages,
                                             {"k": rep, "v": rep})

        # prefix-hash -> state-snapshot store; snapshots are promoted
        # back to device with the pools' mesh sharding on a host-tier
        # hit, and evicted paged entries hand their pages back to the
        # allocator (repro/serving/prefix_cache.py)
        self.prefix_cache: Optional[PrefixCache] = None
        if self._pc_cfg is not None:
            self.prefix_cache = PrefixCache(
                self._pc_cfg, to_device=self._snapshot_to_device,
                release_pages=(self._alloc.release if self._paged
                               else None))

        self._slots: list[Optional[_Slot]] = [None] * max_slots
        self._active = np.zeros(max_slots, bool)
        self._temps = np.zeros(max_slots, np.float32)
        self._top_ks = np.zeros(max_slots, np.int32)
        self._top_ps = np.ones(max_slots, np.float32)
        self._toks = np.zeros(max_slots, np.int32)
        self._uids = np.zeros(max_slots, np.int32)
        self._prefill_order: list[int] = []    # slot idx, admission FIFO
        self._queue: list[Request] = []        # sorted by arrival_time
        self._key = jax.random.PRNGKey(seed)
        self._t0: Optional[float] = None
        self._ttfts: list[float] = []
        # -- overlap pipeline state (all None/empty when overlap=False) -
        # device-resident token feed: decode reads last step's sampled
        # tokens from here without a host round-trip
        self._feed = jnp.zeros((max_slots,), jnp.int32)
        # double-buffered host staging for packed chunk tokens
        self._pack = slot_ops.PackBuffer(max_slots, _next_pow2(max_len))
        self._next_chunk: Optional[dict] = None     # packed, undispatched
        self._pending_merge: Optional[dict] = None  # landed, unmerged
        self._inflight: Optional[dict] = None       # sampled, unfetched
        self._dispatch_seq = 0          # jitted dispatches issued so far
        self._stall_ms: list[float] = []        # per-retire blocked time
        self._depths: list[int] = []            # per-retire queue depth
        self._stats = {"decode_steps": 0, "decode_slot_steps": 0,
                       "prefill_tokens": 0, "prefill_chunks": 0,
                       "prefill_calls": 0, "prefill_padded_tokens": 0,
                       "prefill_rows_max": 0,
                       "max_prefill_tokens_per_step": 0,
                       "emitted_tokens": 0, "admitted": 0, "finished": 0,
                       "forked_requests": 0, "forked_tokens": 0}

        cfg_ = cfg  # closed over by the jitted steps

        def _constrain(tree):
            if pool_shardings is None:
                return tree
            return jax.lax.with_sharding_constraint(tree, pool_shardings)

        def _decode(params, proj, pool, toks, active, all_active):
            logits, new = lm.decode_step(params, cfg_, toks, pool,
                                         proj=proj)
            new = slot_ops.freeze_inactive(pool, new, active,
                                           all_active=all_active)
            return logits, _constrain(new)

        def _prefill(params, proj, staging, toks, idx, valid_len):
            # gather the P staged rows, advance them over one padded
            # (P, L) chunk, scatter them back — ONE device program per
            # step regardless of how many admissions are in flight;
            # with the precomposed proj the chunk runs the fused
            # prf_fused_prefill megakernel (one pallas_call per layer)
            sub = slot_ops.read_slots(staging, idx)
            logits, new = lm.prefill_chunk(params, cfg_, {"tokens": toks},
                                           sub, valid_len=valid_len,
                                           proj=proj)
            return logits, _constrain(slot_ops.write_slots(staging, new,
                                                           idx))

        def _commit(pool, staging, idx):
            # finished admissions: one fused gather+scatter promotes the
            # staged rows into the slot pool (the deferred merge of the
            # overlapped loop rides this same scatter)
            return _constrain(slot_ops.merge_slots(pool, staging, idx))

        def _reset(staging, fresh, idx):
            # one broadcast scatter seeds every slot admitted this step
            # — from the fresh one-row template, or from a cached prefix
            # snapshot (fork-on-admit: the prefix cache's O(1) fork IS
            # this scatter, repro/serving/prefix_cache.py)
            return _constrain(slot_ops.fork_slots(staging, fresh, idx))

        def _snap(staging, idx):
            # one-row snapshot gather for prefix capture; read_slots
            # keeps the slot axis, so the row round-trips through the
            # seed scatters above
            return slot_ops.read_slots(staging, idx)

        def _decode_paged(params, proj, pool, pages, toks, active,
                          all_active):
            # paged exact layout: graft the shared page pools into the
            # detached slot tree around the step, split them back out
            # after (pages are donated through, like the pool)
            st = lm.attach_kv_pages(pool, pages)
            logits, new = lm.decode_step(params, cfg_, toks, st,
                                         proj=proj)
            new, pages = lm.detach_kv_pages(new)
            new = slot_ops.freeze_inactive(pool, new, active,
                                           all_active=all_active)
            return logits, _constrain(new), pages

        def _prefill_paged(params, proj, staging, pages, toks, idx,
                           valid_len):
            sub = slot_ops.read_slots(staging, idx)
            logits, new = lm.prefill_chunk(
                params, cfg_, {"tokens": toks},
                lm.attach_kv_pages(sub, pages), valid_len=valid_len,
                proj=proj)
            new, pages = lm.detach_kv_pages(new)
            return (logits,
                    _constrain(slot_ops.write_slots(staging, new, idx)),
                    pages)

        def _seed_paged(staging, row, idx, tables):
            # paged admission/fork seed: broadcast the snapshot (or
            # fresh) row, but give every seeded slot its OWN page table
            # — shared prefix pages + freshly allocated growth pages
            k = idx.shape[0]
            rows = slot_ops.tree_slot_map(
                lambda p, axis: jnp.repeat(p, k, axis=axis), row)
            la = rows["layers"]
            rows["layers"] = la._replace(table=jnp.broadcast_to(
                tables[None], (la.table.shape[0],) + tables.shape))
            return _constrain(slot_ops.write_slots(staging, rows, idx))

        def _copy_pages(pages, src, dst):
            # copy-on-write at fork: duplicate the partial tail pages
            # ``src`` into ``dst`` across the k/v pools of every layer
            return {n: p.at[:, dst].set(jnp.take(p, src, axis=1))
                    for n, p in pages.items()}

        def _scatter_toks(feed, idx, vals):
            # merge first tokens into the device token feed
            return feed.at[idx].set(vals)

        def _row_keys(uids, counts):
            # schedule-invariant per-row sampling keys: (uid, token
            # index) — independent of step count and batch composition,
            # so a row's draws are identical under every scheduler
            base = self._key
            return jax.vmap(lambda u, n: jax.random.fold_in(
                jax.random.fold_in(base, u), n))(uids, counts)

        def _sample_plain(logits, uids, counts, temps):
            # greedy / plain-temperature rows only: skips the two
            # full-vocab sorts of the top-k/p masks on the hot loop
            greedy = jnp.argmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
            keys = _row_keys(uids, counts)
            drawn = jax.vmap(jax.random.categorical)(keys, scaled)
            return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)

        def _sample(logits, uids, counts, temps, top_ks, top_ps):
            v = logits.shape[-1]
            greedy = jnp.argmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
            # per-row top-k: drop logits below the k-th largest
            # (top_k <= 0 disables; the mask is then all-True)
            desc = jnp.sort(scaled, axis=-1)[:, ::-1]
            kidx = jnp.clip(jnp.where(top_ks > 0, top_ks, v) - 1, 0, v - 1)
            kth = jnp.take_along_axis(desc, kidx[:, None], axis=-1)
            masked = jnp.where(scaled >= kth, scaled, -jnp.inf)
            # per-row nucleus: keep the smallest prefix of probability
            # mass >= top_p (top_p >= 1 disables)
            probs = jax.nn.softmax(masked, axis=-1)
            sp = jnp.sort(probs, axis=-1)[:, ::-1]
            cum = jnp.cumsum(sp, axis=-1)
            keep = ((cum - sp) < top_ps[:, None]) | (top_ps[:, None] >= 1.0)
            cutoff = jnp.min(jnp.where(keep, sp, jnp.inf), axis=-1,
                             keepdims=True)
            masked = jnp.where(probs >= cutoff, masked, -jnp.inf)
            keys = _row_keys(uids, counts)
            drawn = jax.vmap(jax.random.categorical)(keys, masked)
            return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)

        def _first_plain(logits, ridx, uids, counts, temps):
            return _sample_plain(jnp.take(logits, ridx, axis=0),
                                 uids, counts, temps)

        def _first(logits, ridx, uids, counts, temps, top_ks, top_ps):
            return _sample(jnp.take(logits, ridx, axis=0),
                           uids, counts, temps, top_ks, top_ps)

        if self._paged:
            self._decode_fn = jax.jit(_decode_paged,
                                      donate_argnums=(2, 3),
                                      static_argnums=(6,))
            self._prefill_fn = jax.jit(_prefill_paged,
                                       donate_argnums=(2, 3))
            self._seed_fn = jax.jit(_seed_paged, donate_argnums=(0,))
            self._copy_pages_fn = jax.jit(_copy_pages,
                                          donate_argnums=(0,))
        else:
            self._decode_fn = jax.jit(_decode, donate_argnums=(2,),
                                      static_argnums=(5,))
            self._prefill_fn = jax.jit(_prefill, donate_argnums=(2,))
        self._snap_fn = jax.jit(_snap)
        self._commit_fn = jax.jit(_commit, donate_argnums=(0,))
        self._reset_fn = jax.jit(_reset, donate_argnums=(0,))
        self._scatter_fn = jax.jit(_scatter_toks, donate_argnums=(0,))
        self._sample_fn = jax.jit(_sample)
        self._sample_plain_fn = jax.jit(_sample_plain)
        self._first_fn = jax.jit(_first)
        self._first_plain_fn = jax.jit(_first_plain)

    # -- introspection ----------------------------------------------------

    def _resolve_serve_paths(self) -> dict:
        """Name the attention implementation each jitted step compiled:
        ``fused_kernel`` (the prf_fused_prefill / prf_fused_decode
        megakernels against the engine-precomposed projections — what
        ``cfg.use_kernel`` always selects here, since the engine builds
        the projections at construction; the two-stage kernel path is
        reachable only through the lm-level ``fused=False`` oracle
        entry points, never through the engine), ``jnp`` (pure-XLA
        reference), ``exact`` (softmax over per-slot KV pages — no
        Pallas path), or ``none`` (no attention blocks, e.g. pure-RWKV
        stacks)."""
        cfg = self.cfg
        if not any(k in ("attn", "local") for k in cfg.layer_kinds()):
            path = "none"
        elif cfg.attn.kind == "exact":
            # "exact_paged": softmax over a block-granular page table
            # into the shared page pools (prefix-cache engines)
            path = "exact_paged" if self._paged else "exact"
        elif self._decode_proj is not None:
            path = "fused_kernel"
        else:
            path = "jnp"
        return {"prefill_path": path, "decode_path": path}

    def _snapshot_to_device(self, tree):
        """Promote a host-tier prefix snapshot back to device, with the
        pools' mesh sharding when the engine runs sharded (the b=1 slot
        dims replicate under ``serve_state_specs``)."""
        if self.mesh is None:
            return jax.device_put(tree)
        from repro.parallel import serve_state_specs, make_shardings
        return jax.device_put(
            tree, make_shardings(serve_state_specs(tree, self.mesh),
                                 self.mesh))

    # -- clock ------------------------------------------------------------

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return time.monotonic() - self._t0

    # -- client API -------------------------------------------------------

    def submit(self, req: Union[Request, Sequence[int]], **kw) -> int:
        """Queue a request (or a bare token prompt). Returns its uid.

        Validates everything that would otherwise fail opaquely (or
        silently clamp) inside the jitted step functions: empty prompts,
        prompts that don't fit the per-slot ``max_len`` context budget
        alongside at least one generated token, out-of-vocab token ids,
        and degenerate sampling parameters.
        """
        if not isinstance(req, Request):
            req = Request(prompt=list(req), **kw)
        if len(req.prompt) == 0:
            raise ValueError("empty prompt: a request must carry at least "
                             "one prompt token")
        if len(req.prompt) + 1 > self.max_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} does not fit max_len "
                f"{self.max_len}: a slot's context page must hold the "
                f"prompt plus at least one generated token "
                f"(prompt <= max_len - 1 = {self.max_len - 1})")
        lo, hi = min(req.prompt), max(req.prompt)
        if lo < 0 or hi >= self.cfg.vocab:
            raise ValueError(
                f"prompt token ids must lie in the vocab range "
                f"[0, {self.cfg.vocab}) (got min={lo}, max={hi}); "
                f"out-of-range ids would be silently clamped by the "
                f"embedding gather inside jit")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (admission "
                             "always samples the first token)")
        if req.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if req.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if req.top_p <= 0:
            # top_p <= 0 would mask EVERY token to -inf and the row
            # would silently stream token 0
            raise ValueError("top_p must be > 0 (>= 1.0 disables)")
        bisect.insort(self._queue, req, key=lambda r: r.arrival_time)
        return req.uid

    def cancel(self, uid: int) -> Optional[RequestResult]:
        """Evict a queued, mid-prefill or mid-decode request. Returns its
        partial result (None if the uid is unknown).

        Under the overlapped loop a cancelled request's in-flight work
        is dropped, not flushed: tokens already sampled on device but
        not yet retired are discarded (no ``on_token`` callback), a
        packed-but-undispatched prefill chunk row is skipped at
        dispatch, and a landed-but-unmerged staging row is never
        committed — so the partial result holds exactly the tokens the
        host had observed, the same cut as the sequential scheduler.
        """
        for i, req in enumerate(self._queue):
            if req.uid == uid:
                self._queue.pop(i)
                return RequestResult(uid=uid, prompt=list(req.prompt),
                                     arrival_time=req.arrival_time,
                                     cancelled=True)
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.req.uid == uid:
                res = slot.result
                res.cancelled = True
                res.finish_time = self._now()
                self._free(i)
                return res
        return None

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_prefilling(self) -> int:
        return len(self._prefill_order)

    @property
    def has_work(self) -> bool:
        return (bool(self._queue)
                or any(s is not None for s in self._slots)
                or self._inflight is not None)

    def next_arrival(self) -> Optional[float]:
        return self._queue[0].arrival_time if self._queue else None

    @property
    def _pipeline_idle(self) -> bool:
        """No in-flight or staged work anywhere in the pipeline — safe
        to jump the clock to the next arrival."""
        return (self.num_active == 0 and not self._prefill_order
                and self._next_chunk is None
                and self._pending_merge is None
                and self._inflight is None)

    # -- scheduler --------------------------------------------------------

    def _free(self, i: int) -> None:
        self._slots[i] = None
        self._active[i] = False
        self._temps[i] = 0.0
        self._top_ks[i] = 0
        self._top_ps[i] = 1.0
        self._uids[i] = 0
        if i in self._prefill_order:
            self._prefill_order.remove(i)
        if self._paged and self._slot_pages[i] is not None:
            # don't release the pages yet: dispatches already enqueued
            # against this row (a lock-step decode, an in-flight chunk)
            # may still write through its table. _flush_freed zeroes the
            # table first — routing any zombie write to the garbage
            # page — then hands the pages back.
            self._pending_clear.append(i)

    def _flush_freed(self) -> None:
        """Zero the pool/staging page tables of slots freed since the
        last step, then release their pages. Runs at the head of every
        step, BEFORE admissions can reallocate the pages: the table
        resets are enqueued behind any straggling writes (single-stream
        dispatch order), so a reallocated page can never be clobbered by
        a freed row's in-flight tail."""
        if not self._paged or not self._pending_clear:
            return
        idx = jnp.asarray(sorted(set(self._pending_clear)), jnp.int32)
        self.pool = self._reset_fn(self.pool, self._fresh_row, idx)
        self.staging = self._reset_fn(self.staging, self._fresh_row, idx)
        self._dispatch_seq += 2
        for i in set(self._pending_clear):
            pages = self._slot_pages[i]
            self._slot_pages[i] = None
            if pages:
                self._alloc.release(pages)
        self._pending_clear.clear()

    def _activate(self, i: int) -> None:
        """Load slot i's sampling params into the batched host arrays."""
        slot = self._slots[i]
        self._active[i] = True
        self._temps[i] = slot.req.temperature
        self._top_ks[i] = slot.req.top_k
        self._top_ps[i] = slot.req.top_p
        self._uids[i] = slot.req.uid

    def _sample_one(self, req: Request, logits_row: Array,
                    count: int) -> int:
        """Sample one row with its schedule-invariant (uid, count) key.
        ``count`` is the row's token index (0 = the first token sampled
        at admission)."""
        uids = jnp.full((1,), req.uid, jnp.int32)
        counts = jnp.full((1,), count, jnp.int32)
        temps = jnp.full((1,), req.temperature, jnp.float32)
        if req.top_k <= 0 and req.top_p >= 1.0:
            return int(self._sample_plain_fn(logits_row, uids, counts,
                                             temps)[0])
        return int(self._sample_fn(
            logits_row, uids, counts, temps,
            jnp.full((1,), req.top_k, jnp.int32),
            jnp.full((1,), req.top_p, jnp.float32))[0])

    def _paged_admit_pages(self, req: Request, ent) -> tuple:
        """Build an admission's page table: the cached prefix's fully
        covered pages are SHARED (refcount retained), its partial tail
        page is queued for a copy-on-write duplication, and fresh pages
        cover the rest of prompt + generation budget. Returns (table
        (max_pages,) int32, owned page ids, [(src, dst)] tail copies).
        Raises NoFreePages (after trying a cache reclaim, with the
        match's own refcounts unwound) to defer the admission."""
        ps = self._page_size
        budget = min(req.max_new_tokens, self.max_len - len(req.prompt))
        n_total = -(-(len(req.prompt) + budget) // ps)
        n_shared = 0 if ent is None else len(ent.tokens) // ps
        shared = [] if ent is None else list(ent.pages[:n_shared])
        tail_src = (ent.pages[n_shared]
                    if ent is not None and len(ent.tokens) % ps else None)
        n_new = n_total - n_shared
        # Pin the match BEFORE any reclaim/alloc: without exclude=ent a
        # reclaim could evict the very entry being forked, dropping its
        # pages into the LIFO free list where alloc() re-issues them as
        # this request's writable growth pages (double-booked prefix
        # pages, silent KV corruption). The retains double as the
        # slot's own refs on the fully shared pages; the extra tail-src
        # ref keeps the CoW source alive even if a later admission in
        # the same batch evicts the entry — _admissions releases it
        # once the batched copy is dispatched.
        pinned = shared + ([] if tail_src is None else [tail_src])
        self._alloc.retain(pinned)
        try:
            if n_new > self._alloc.n_free:
                self.prefix_cache.reclaim_pages(self._alloc, n_new,
                                                exclude=ent)
            fresh = self._alloc.alloc(n_new)      # raises NoFreePages
        except NoFreePages:
            self._alloc.release(pinned)
            raise
        copies = [] if tail_src is None else [(tail_src, fresh[0])]
        own = shared + fresh
        table = np.zeros(self._max_pages, np.int32)
        table[:len(own)] = own
        return table, own, copies

    def _seed(self, row: dict, idxs: list, tables: list) -> None:
        """Seed staging rows ``idxs`` from the one-row state ``row`` in
        one broadcast scatter (paged rows also get their own tables)."""
        idx = jnp.asarray(idxs, jnp.int32)
        if self._paged:
            self.staging = self._seed_fn(self.staging, row, idx,
                                         jnp.asarray(np.stack(tables)))
        else:
            self.staging = self._reset_fn(self.staging, row, idx)
        self._dispatch_seq += 1

    def _admissions(self, now: float) -> None:
        """Reserve a free slot (freshly seeded staging row) for every
        arrived request, FIFO. With a prefix cache, admission first
        matches the longest cached prefix and seeds the staging row from
        its snapshot instead of the fresh template (fork-on-admit): the
        slot's cursor starts at the cached length and chunked prefill
        resumes from there, so only the un-cached suffix is computed.
        Same-entry admissions share one broadcast seed scatter; paged
        admissions allocate their page tables here and defer (stay
        queued) when the page pool is exhausted even after evicting
        cached prefixes."""
        fresh_adm: list[int] = []
        fresh_tables: list = []
        forks: dict[str, list] = {}    # entry key -> [ent, idxs, tables]
        copies: list[tuple[int, int]] = []
        while self._queue and self._queue[0].arrival_time <= now:
            free = [i for i in range(self.max_slots)
                    if self._slots[i] is None]
            if not free:
                break
            req = self._queue[0]
            ent = (self.prefix_cache.match(req.prompt)
                   if self.prefix_cache is not None else None)
            table = own = None
            if self._paged:
                try:
                    table, own, cps = self._paged_admit_pages(req, ent)
                except NoFreePages:
                    # backpressure: requeue (it never left the queue)
                    # and undo the match stat so the retry next step
                    # doesn't double-count
                    if ent is not None:
                        self.prefix_cache.hits -= 1
                    else:
                        self.prefix_cache.misses -= 1
                    break
                copies.extend(cps)
            self._queue.pop(0)
            i = free[0]
            result = RequestResult(uid=req.uid,
                                   prompt=list(map(int, req.prompt)),
                                   arrival_time=req.arrival_time)
            # exact-cache pages hold max_len keys: prompt + decoded tokens
            budget = min(req.max_new_tokens,
                         self.max_len - len(req.prompt))
            self._slots[i] = _Slot(req, result, budget)
            self._slot_pages[i] = own
            self._prefill_order.append(i)
            if ent is not None:
                self._slots[i].cursor = len(ent.tokens)
                self._stats["forked_requests"] += 1
                self._stats["forked_tokens"] += len(ent.tokens)
                grp = forks.setdefault(ent.key, [ent, [], []])
                grp[1].append(i)
                grp[2].append(table)
            else:
                fresh_adm.append(i)
                fresh_tables.append(table)
        if copies:
            # one batched CoW duplication for every forked tail page
            self._pages = self._copy_pages_fn(
                self._pages, jnp.asarray([s for s, _ in copies],
                                         jnp.int32),
                jnp.asarray([d for _, d in copies], jnp.int32))
            self._dispatch_seq += 1
            # drop the tail-src pins taken in _paged_admit_pages: the
            # copies are enqueued, and dispatch order protects their
            # source contents from any later page reuse
            self._alloc.release([s for s, _ in copies])
        if fresh_adm:
            self._seed(self._fresh_row, fresh_adm, fresh_tables)
        for ent, idxs, tables in forks.values():
            self._seed(self.prefix_cache.device_state(ent), idxs, tables)

    def _plan_prefill(self) -> list[tuple[int, int]]:
        """Token-budget packer: split this step's prompt-token budget
        across the staged admissions, FIFO. Returns [(slot, tokens)].

        Blocking mode (``chunk_tokens=None``) grants every staged
        admission its full remaining prompt. Chunked + bucketed mode
        COALESCES: every staged row gets the same pow-2 grant
        ``g = prev_pow2(chunk_tokens // rows)``, so all non-tail rows
        land in one shared length bucket with ZERO padding waste —
        ``prefill_batch_occupancy`` is 1.0 under ragged admission
        bursts until the rows' last partial chunks. Unbucketed chunked
        mode keeps the legacy FIFO ceil-shares (the serial bit-exact
        contract at ``prefill_rows=1``). Either way at most
        ``chunk_tokens`` prompt tokens total run between two decode
        steps (the invariant the latency benchmark measures).
        """
        staged = self._prefill_order
        if self.prefill_rows is not None:
            staged = staged[:self.prefill_rows]
        grants: list[tuple[int, int]] = []
        if self.chunk_tokens is None:
            for i in staged:
                slot = self._slots[i]
                grants.append((i, len(slot.req.prompt) - slot.cursor))
            return grants
        budget = self.chunk_tokens
        if self.bucket_prefill and staged and budget >= len(staged):
            # coalesced equal-length grants: one bucket, no padding
            g = 1 << ((budget // len(staged)).bit_length() - 1)
            for i in staged:
                slot = self._slots[i]
                grants.append((i, min(len(slot.req.prompt) - slot.cursor,
                                      g)))
            return grants
        for j, i in enumerate(staged):
            if budget <= 0:
                break
            slot = self._slots[i]
            rem = len(slot.req.prompt) - slot.cursor
            share = -(-budget // (len(staged) - j))      # ceil division
            t = min(rem, share)
            grants.append((i, t))
            budget -= t
        return grants

    def _record_prefill_stats(self, n_rows: int, spent: int,
                              l_pad: int) -> None:
        self._stats["prefill_tokens"] += spent
        self._stats["prefill_chunks"] += n_rows
        self._stats["prefill_calls"] += 1
        self._stats["prefill_padded_tokens"] += n_rows * l_pad
        self._stats["prefill_rows_max"] = max(
            self._stats["prefill_rows_max"], n_rows)
        self._stats["max_prefill_tokens_per_step"] = max(
            self._stats["max_prefill_tokens_per_step"], spent)

    def _maybe_capture(self, i: int) -> None:
        """Capture a prefix snapshot of slot i's staging row when its
        prefill cursor just crossed a ``block_tokens`` boundary (or, with
        ``capture_final``, completed the prompt — the multi-turn reuse
        point). The snapshot is a one-row gather of the staging pool;
        paged rows additionally retain their covering prefix pages so
        the entry keeps them alive after the donor slot is freed."""
        pc = self.prefix_cache
        if pc is None:
            return
        slot = self._slots[i]
        cur = slot.cursor
        bt = pc.cfg.block_tokens
        final = cur == len(slot.req.prompt)
        if not ((cur > 0 and cur % bt == 0)
                or (final and pc.cfg.capture_final)):
            return
        tokens = slot.req.prompt[:cur]
        if pc.has(tokens):
            return
        snap = self._snap_fn(self.staging, jnp.asarray([i], jnp.int32))
        self._dispatch_seq += 1
        if self._paged:
            n_cov = -(-cur // self._page_size)
            pages = list(self._slot_pages[i][:n_cov])
            self._alloc.retain(pages)
            pc.put(tokens, snap, pages=pages,
                   page_bytes=n_cov * self._page_bytes_each)
        else:
            pc.put(tokens, snap)

    # -- sequential scheduler ---------------------------------------------

    def _prefill_work(self) -> None:
        """Advance every scheduled admission by its granted chunk in ONE
        padded batched ``prefill_chunk`` call, then commit + activate the
        admissions whose prompts finished (also batched)."""
        grants = self._plan_prefill()
        if not grants:
            return
        ts = np.asarray([t for _, t in grants], np.int32)
        l_pad = int(ts.max())
        if self.bucket_prefill:
            l_pad = _next_pow2(l_pad)
        toks = self._pack.pack(
            [self._slots[i].req.prompt[self._slots[i].cursor:
                                       self._slots[i].cursor + t]
             for i, t in grants], l_pad)
        # all-full rows take the legacy unpadded path (bit-exact with the
        # serial schedule); ragged rows carry per-row valid lengths
        vl = None if (ts == l_pad).all() else jnp.asarray(ts)
        idx = jnp.asarray([i for i, _ in grants], jnp.int32)
        if self._paged:
            logits, self.staging, self._pages = self._prefill_fn(
                self._step_params, self._decode_proj, self.staging,
                self._pages, jnp.asarray(toks), idx, vl)
        else:
            logits, self.staging = self._prefill_fn(
                self._step_params, self._decode_proj, self.staging,
                jnp.asarray(toks), idx, vl)
        self._dispatch_seq += 1
        self._record_prefill_stats(len(grants), int(ts.sum()), l_pad)

        done: list[tuple[int, int]] = []
        for r, (i, t) in enumerate(grants):
            slot = self._slots[i]
            slot.cursor += t
            self._maybe_capture(i)
            if slot.cursor == len(slot.req.prompt):
                done.append((r, i))
        if not done:
            return
        self.pool = self._commit_fn(
            self.pool, self.staging,
            jnp.asarray([i for _, i in done], jnp.int32))
        self._dispatch_seq += 1
        for r, i in done:
            self._prefill_order.remove(i)
            self._finish_admission(i, logits[r:r + 1])

    def _finish_admission(self, i: int, logits: Array) -> None:
        """Activate pool row i (already committed from staging). Blocks
        on the sampled first token — readiness, not dispatch — before
        stamping its time."""
        slot = self._slots[i]
        first = self._sample_one(slot.req, logits, count=0)
        now = self._now()
        if slot.req.on_token is not None:
            slot.req.on_token(first, now)
        slot.result.admit_time = now
        slot.result.tokens = [first]
        slot.result.token_times = [now]
        slot.emitted = 1
        self._ttfts.append(now - slot.req.arrival_time)
        self._activate(i)
        self._toks[i] = first
        self._stats["emitted_tokens"] += 1
        self._stats["admitted"] += 1

    # -- overlapped scheduler ---------------------------------------------

    def _retire(self, finished: list[RequestResult]) -> None:
        """Fetch the one-step-delayed token buffers, append the now-ready
        tokens, evict finished rows. The ONLY blocking point of the
        overlapped loop; the blocked time is the step's decode stall."""
        rec = self._inflight
        if rec is None:
            return
        self._inflight = None
        t0 = time.perf_counter()
        first = rec["first"]
        dec = rec["decode"]
        first_np = np.asarray(first[2]) if first is not None else None
        dec_np = np.asarray(dec[2]) if dec is not None else None
        self._stall_ms.append((time.perf_counter() - t0) * 1e3)
        self._depths.append(self._dispatch_seq - rec["seq"])
        now = self._now()
        done_now: set[int] = set()
        if first is not None:
            for i, uid, tok in zip(first[0], first[1], first_np):
                slot = self._slots[i]
                if slot is None or slot.req.uid != uid:
                    continue               # cancelled while in flight
                tok = int(tok)
                if slot.req.on_token is not None:
                    slot.req.on_token(tok, now)
                slot.result.admit_time = now
                slot.result.tokens = [tok]
                slot.result.token_times = [now]
                self._ttfts.append(now - slot.req.arrival_time)
                self._toks[i] = tok
                self._stats["emitted_tokens"] += 1
                self._stats["admitted"] += 1
                if self._done(slot):
                    # finished on its first token: the decode that ran
                    # concurrently was speculative — drop its token
                    done_now.add(i)
                    finished.append(self._finish(i))
        if dec is not None:
            self._stats["decode_steps"] += 1
            self._stats["decode_slot_steps"] += len(dec[0])
            for i, uid in zip(dec[0], dec[1]):
                if i in done_now:
                    continue
                slot = self._slots[i]
                if slot is None or slot.req.uid != uid:
                    continue               # cancelled while in flight
                tok = int(dec_np[i])
                if slot.req.on_token is not None:
                    slot.req.on_token(tok, now)
                slot.result.tokens.append(tok)
                slot.result.token_times.append(now)
                self._toks[i] = tok
                self._stats["emitted_tokens"] += 1
                if self._done(slot):
                    finished.append(self._finish(i))

    def _merge_pending(self) -> Optional[tuple]:
        """Commit admissions whose final chunk landed last step into the
        slot pool (one deferred merge scatter), sample their first
        tokens from the saved final-chunk logits, and scatter them into
        the device token feed — all dispatched AHEAD of this step's
        decode. Returns the retire record (slots, uids, tokens_dev)."""
        pm = self._pending_merge
        if pm is None:
            return None
        self._pending_merge = None
        keep = [(i, uid, r) for i, uid, r in pm["rows"]
                if self._slots[i] is not None
                and self._slots[i].req.uid == uid]
        if not keep:
            return None
        idx_np = np.asarray([i for i, _, _ in keep], np.int32)
        idx = jnp.asarray(idx_np)
        self.pool = self._commit_fn(self.pool, self.staging, idx)
        self._dispatch_seq += 1
        ridx = jnp.asarray([r for _, _, r in keep], jnp.int32)
        uids = np.asarray([uid for _, uid, _ in keep], np.int32)
        counts = np.zeros(len(keep), np.int32)       # first token: index 0
        reqs = [self._slots[i].req for i, _, _ in keep]
        temps = np.asarray([q.temperature for q in reqs], np.float32)
        tks = np.asarray([q.top_k for q in reqs], np.int32)
        tps = np.asarray([q.top_p for q in reqs], np.float32)
        if (tks > 0).any() or (tps < 1.0).any():
            toks = self._first_fn(pm["logits"], ridx, jnp.asarray(uids),
                                  jnp.asarray(counts), jnp.asarray(temps),
                                  jnp.asarray(tks), jnp.asarray(tps))
        else:
            toks = self._first_plain_fn(pm["logits"], ridx,
                                        jnp.asarray(uids),
                                        jnp.asarray(counts),
                                        jnp.asarray(temps))
        self._dispatch_seq += 1
        seq = self._dispatch_seq        # producing dispatch, for depth
        self._feed = self._scatter_fn(self._feed, idx, toks)
        self._dispatch_seq += 1
        for i, _, _ in keep:
            self._activate(i)
            self._slots[i].emitted = 1
        return (list(idx_np), list(uids), toks, seq)

    def _dispatch_decode(self) -> Optional[tuple]:
        """Enqueue one batched decode + sample over the active rows,
        reading the token feed straight from device. Returns the retire
        record (rows, uids, tokens_dev) fetched NEXT step."""
        rows = np.nonzero(self._active)[0]
        if rows.size == 0:
            return None
        counts = np.zeros(self.max_slots, np.int32)
        for i in rows:
            counts[i] = self._slots[i].emitted
        if self._paged:
            logits, self.pool, self._pages = self._decode_fn(
                self._step_params, self._decode_proj, self.pool,
                self._pages, self._feed, jnp.asarray(self._active),
                bool(self._active.all()))
        else:
            logits, self.pool = self._decode_fn(
                self._step_params, self._decode_proj, self.pool,
                self._feed, jnp.asarray(self._active),
                bool(self._active.all()))
        self._dispatch_seq += 1
        uids = jnp.asarray(self._uids)
        counts_j = jnp.asarray(counts)
        if (self._top_ks > 0).any() or (self._top_ps < 1.0).any():
            toks = self._sample_fn(logits, uids, counts_j,
                                   jnp.asarray(self._temps),
                                   jnp.asarray(self._top_ks),
                                   jnp.asarray(self._top_ps))
        else:
            toks = self._sample_plain_fn(logits, uids, counts_j,
                                         jnp.asarray(self._temps))
        self._dispatch_seq += 1
        # the sampled buffer IS the next feed: merged rows' first tokens
        # are scattered on top next step, inactive rows are don't-care
        self._feed = toks
        for i in rows:
            self._slots[i].emitted += 1
        return (list(rows), [int(self._uids[i]) for i in rows], toks,
                self._dispatch_seq)

    def _dispatch_prefill(self) -> None:
        """Enqueue the chunk packed last step (behind this step's
        decode). Rows cancelled since packing are dropped; rows whose
        prompt completes queue the deferred merge for next step."""
        ch = self._next_chunk
        if ch is None:
            return
        self._next_chunk = None
        live = [j for j, (i, uid, _) in enumerate(ch["grants"])
                if self._slots[i] is not None
                and self._slots[i].req.uid == uid]
        if not live:
            return
        grants = [ch["grants"][j] for j in live]
        toks = ch["toks"]
        if len(live) != len(ch["grants"]):
            toks = toks[live]
        ts = np.asarray([t for _, _, t in grants], np.int32)
        l_pad = ch["l_pad"]
        vl = None if (ts == l_pad).all() else jnp.asarray(ts)
        idx = jnp.asarray([i for i, _, _ in grants], jnp.int32)
        if self._paged:
            logits, self.staging, self._pages = self._prefill_fn(
                self._step_params, self._decode_proj, self.staging,
                self._pages, jnp.asarray(toks), idx, vl)
        else:
            logits, self.staging = self._prefill_fn(
                self._step_params, self._decode_proj, self.staging,
                jnp.asarray(toks), idx, vl)
        self._dispatch_seq += 1
        self._record_prefill_stats(len(grants), int(ts.sum()), l_pad)
        done: list[tuple[int, int, int]] = []
        for r, (i, uid, t) in enumerate(grants):
            slot = self._slots[i]
            slot.cursor += t
            self._maybe_capture(i)
            if slot.cursor == len(slot.req.prompt):
                self._prefill_order.remove(i)
                done.append((i, uid, r))
        if done:
            self._pending_merge = {"rows": done, "logits": logits}

    def _pack_next_chunk(self) -> None:
        """Plan + pack the NEXT prefill chunk into the idle half of the
        double buffer while this step's chunk is still in flight."""
        grants = self._plan_prefill()
        if not grants:
            return
        ts = np.asarray([t for _, t in grants], np.int32)
        l_pad = int(ts.max())
        if self.bucket_prefill:
            l_pad = _next_pow2(l_pad)
        toks = self._pack.pack(
            [self._slots[i].req.prompt[self._slots[i].cursor:
                                       self._slots[i].cursor + t]
             for i, t in grants], l_pad)
        self._next_chunk = {
            "grants": [(i, self._slots[i].req.uid, t) for i, t in grants],
            "toks": toks, "l_pad": l_pad}

    def _step_overlap(self) -> list[RequestResult]:
        """One turn of the pipelined loop — see the module docstring's
        retire/admit/merge/decode/prefill/pack timeline."""
        finished: list[RequestResult] = []
        self._retire(finished)
        self._flush_freed()
        self._admissions(self._now())
        first_rec = self._merge_pending()
        decode_rec = self._dispatch_decode()
        self._dispatch_prefill()
        self._pack_next_chunk()
        if first_rec is not None or decode_rec is not None:
            # depth baseline: the EARLIEST producing sample dispatch —
            # everything enqueued after it (token-feed scatter, prefill
            # chunk) is work the device queue runs ahead with
            seq = min(r[3] for r in (first_rec, decode_rec)
                      if r is not None)
            self._inflight = {"first": first_rec, "decode": decode_rec,
                              "seq": seq}
        return finished

    def flush(self) -> list[RequestResult]:
        """Drain the overlap pipeline's in-flight tail without
        dispatching new work: retire the delayed token buffer, apply any
        pending merge (whose first tokens are then retired too). After
        ``flush()`` every token produced so far is host-visible. No-op
        on the sequential scheduler. Returns newly finished results."""
        finished: list[RequestResult] = []
        while self._inflight is not None or self._pending_merge is not None:
            self._retire(finished)
            rec = self._merge_pending()
            if rec is not None:
                self._inflight = {"first": rec, "decode": None,
                                  "seq": rec[3]}
        return finished

    # -- decode -----------------------------------------------------------

    def step(self) -> list[RequestResult]:
        """Admit what has arrived, advance prefill and decode, evict
        finished sequences. Returns newly finished results (possibly
        empty). Sequential mode runs one packed prefill chunk then one
        blocking batched decode; overlap mode runs the pipelined
        retire/merge/dispatch turn (module docstring)."""
        if self.overlap:
            return self._step_overlap()
        finished: list[RequestResult] = []
        self._flush_freed()
        self._admissions(self._now())
        self._prefill_work()
        # admission may already exhaust a request (budget/eos on token 1)
        for i, slot in enumerate(self._slots):
            if slot is not None and self._active[i] and self._done(slot):
                finished.append(self._finish(i))
        if not self._active.any():
            return finished

        # static all-active flag: a fully occupied pool skips the
        # pool-wide freeze select (bit-identical either way)
        counts = np.zeros(self.max_slots, np.int32)
        for i in np.nonzero(self._active)[0]:
            counts[i] = self._slots[i].emitted
        if self._paged:
            logits, self.pool, self._pages = self._decode_fn(
                self._step_params, self._decode_proj, self.pool,
                self._pages, jnp.asarray(self._toks),
                jnp.asarray(self._active), bool(self._active.all()))
        else:
            logits, self.pool = self._decode_fn(
                self._step_params, self._decode_proj, self.pool,
                jnp.asarray(self._toks), jnp.asarray(self._active),
                bool(self._active.all()))
        self._dispatch_seq += 1
        # host-side check: only pay the full-vocab sort/cumsum masks when
        # some active row actually uses top-k/p (the masks are identity
        # at the defaults, so both paths sample identically)
        if (self._top_ks > 0).any() or (self._top_ps < 1.0).any():
            toks_dev = self._sample_fn(logits, jnp.asarray(self._uids),
                                       jnp.asarray(counts),
                                       jnp.asarray(self._temps),
                                       jnp.asarray(self._top_ks),
                                       jnp.asarray(self._top_ps))
        else:
            toks_dev = self._sample_plain_fn(logits,
                                             jnp.asarray(self._uids),
                                             jnp.asarray(counts),
                                             jnp.asarray(self._temps))
        self._dispatch_seq += 1
        seq_at_sample = self._dispatch_seq
        # block on token READINESS before stamping times (under async
        # dispatch an unblocked perf_counter delta would time the
        # enqueue, not the token)
        t0 = time.perf_counter()
        toks = np.asarray(toks_dev)
        self._stall_ms.append((time.perf_counter() - t0) * 1e3)
        self._depths.append(self._dispatch_seq - seq_at_sample)
        now = self._now()
        n_act = int(self._active.sum())
        self._stats["decode_steps"] += 1
        self._stats["decode_slot_steps"] += n_act
        for i in np.nonzero(self._active)[0]:
            slot = self._slots[i]
            tok = int(toks[i])
            if slot.req.on_token is not None:
                slot.req.on_token(tok, now)
            slot.result.tokens.append(tok)
            slot.result.token_times.append(now)
            slot.emitted += 1
            self._toks[i] = tok
            self._stats["emitted_tokens"] += 1
            if self._done(slot):
                finished.append(self._finish(i))
        return finished

    def _done(self, slot: _Slot) -> bool:
        toks = slot.result.tokens
        if len(toks) >= slot.budget:
            return True
        return slot.req.eos_id is not None and toks[-1] == slot.req.eos_id

    def _finish(self, i: int) -> RequestResult:
        res = self._slots[i].result
        res.finish_time = self._now()
        self._free(i)
        self._stats["finished"] += 1
        return res

    # -- batch runner -----------------------------------------------------

    def run(self, realtime: bool = False) -> list[RequestResult]:
        """Drive ``step()`` until queue, slots and the overlap pipeline
        drain.

        ``realtime=True`` honors future ``arrival_time``s by sleeping
        while the pool is empty (Poisson-traffic benchmarking); otherwise
        arrival order is respected but waits are skipped.
        """
        results: list[RequestResult] = []
        while self.has_work:
            if self._pipeline_idle and self._queue:
                wait = self._queue[0].arrival_time - self._now()
                if wait > 0:
                    if realtime:
                        time.sleep(wait)
                    else:
                        self._t0 -= wait       # jump the clock forward
            results.extend(self.step())
        return results

    # -- metrics ----------------------------------------------------------

    @property
    def stats(self) -> dict:
        s = dict(self._stats)
        s.update(self._serve_paths)
        s["overlap"] = self.overlap
        s["paged_kv"] = self._paged
        if self.prefix_cache is not None:
            s.update(self.prefix_cache.stats)
        if self._paged:
            s["kv_page_size"] = self._page_size
            s["kv_pages_total"] = self._alloc.n_pages
            s["kv_pages_free"] = self._alloc.n_free
        steps = max(s["decode_steps"], 1)
        # fraction of slot-steps that carried a live sequence
        s["mean_occupancy"] = (s["decode_slot_steps"]
                               / (steps * self.max_slots))
        # fraction of the padded (P x L) prefill compute spent on real
        # prompt tokens, and how many admissions each call advanced
        s["prefill_batch_occupancy"] = (
            s["prefill_tokens"] / s["prefill_padded_tokens"]
            if s["prefill_padded_tokens"] else 1.0)
        s["prefill_rows_per_call"] = (
            s["prefill_chunks"] / s["prefill_calls"]
            if s["prefill_calls"] else 0.0)
        if self._ttfts:
            s["ttft_p50"] = float(np.percentile(self._ttfts, 50))
            s["ttft_p99"] = float(np.percentile(self._ttfts, 99))
        # per-step pipeline counters: how long the host blocked for the
        # token buffer (readiness stall) and how many dispatches the
        # device queue ran ahead of the fetched buffer
        if self._stall_ms:
            s["decode_stall_ms_p50"] = float(np.percentile(
                self._stall_ms, 50))
            s["decode_stall_ms_p99"] = float(np.percentile(
                self._stall_ms, 99))
            s["decode_stall_ms_max"] = float(np.max(self._stall_ms))
        if self._depths:
            s["dispatch_depth_mean"] = float(np.mean(self._depths))
            s["dispatch_depth_max"] = int(np.max(self._depths))
        return s
