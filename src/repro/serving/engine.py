"""Continuous-batching serving engine over the O(1)-state PRF decode.

The paper's serving claim (docs/serving.md) is that PRF attention decodes
from a fixed-size running state — an (m x d_v) sum S, an (m,) normalizer
z and the running stabilizer max c per head — so a server can multiplex
many users over one batched decode step regardless of how long each
context is. The same state is what makes prefill *chunkable*: the state
after k prompt tokens is a valid resume point (``lm.prefill_chunk``), so
prompt work can be cut into budgeted slices instead of monopolizing the
device. This engine is that multiplexer:

  * a FIFO **request queue** with arrival times (Poisson traffic plugs in
    here — see benchmarks/serve_latency.py);
  * a device-resident **slot pool**: one serve-state pytree with
    ``max_slots`` batch rows, per-slot positions and (for the exact
    fallback) per-slot KV write indices — plus a same-shape **staging
    pool** holding every mid-prefill admission's partial state
    (repro/serving/slots.py);
  * a **token-budget packer**: each ``step()`` splits at most
    ``chunk_tokens`` prompt tokens across ALL staged admissions and
    advances them together in ONE padded (P, L) ``prefill_chunk`` call
    — under bucketing the grants are COALESCED to one shared pow-2
    size (prev_pow2(budget/P)) so non-tail rows pack with zero padding
    waste (occupancy 1.0 under ragged bursts); ragged rows are masked
    per-row (``valid_len``) and chunk lengths are bucketed to powers
    of two so compiles stay bounded by (rows <= max_slots) x (log2
    length buckets). With ``cfg.use_kernel`` the packed call runs the
    ``prf_fused_prefill`` megakernel against the same engine-built
    projections as decode (one pallas_call per layer per chunk,
    valid_len masked in-kernel, staging rows aliased in place).
    ``chunk_tokens=None`` is the blocking baseline: all staged
    admissions prefill their whole prompts in one padded call;
  * one jitted **batched decode step** that advances all slots in
    lock-step; inactive slots are masked so their state stays bit-frozen
    (skipped entirely — a static fast path — when every slot is live).
    A mid-prefill slot's state lives in the staging pool until its last
    chunk lands, so partial prefills never perturb pool rows. For
    homogeneous configs both pools are LAYER-STACKED
    (``lm.can_stack_layers``): the step scans one compiled layer body
    over a leading (n_layers,) axis, and with ``cfg.use_kernel`` that
    body runs the ``prf_fused_decode`` megakernel against per-layer
    projections precomposed once at engine build
    (``lm.build_decode_proj``).

Pass ``mesh=`` to place BOTH pools under a device mesh: every pool leaf
is sharded per ``repro.parallel.serve_state_specs`` (slots over the data
axes, head groups of the KV-cache / linear state over 'model'),
``device_put`` at construction, donated through every step, and pinned
with ``with_sharding_constraint`` inside the jitted step functions so
XLA never silently migrates the pool. Decode under a mesh is
token-identical to the unsharded engine (tests/test_distributed.py).

Numerical contract: slot rows are computed elementwise over the batch
axis, so a sequence decoded inside a busy heterogeneous batch produces
bit-identical f32 logits to the same sequence decoded alone with
``lm.prefill`` + ``lm.decode_step`` (tests/test_serving_engine.py
asserts this for darkformer, performer and exact kernels). Chunking a
prompt changes the k-stabilizer trajectory (a running max instead of one
whole-prompt max), so chunked admission matches blocking admission to
f32 rounding — and bit-exactly when ``chunk_tokens >= prompt_len``
(tests/test_chunked_prefill.py). Batching staged admissions into one
padded call masks every padded position out of the advanced states, so
batched prefill matches the serial (``prefill_rows=1``) schedule to f32
rounding; with one staged row and ``bucket_prefill=False`` the packed
call IS the legacy unpadded chunk, bit-for-bit.

Sampling: per-request ``temperature`` / ``top_k`` / ``top_p`` are applied
inside one jitted batched sample step; the defaults (0 / 0 / 1.0) leave
the greedy path bit-identical to plain argmax.
"""
from __future__ import annotations

import bisect
import time
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving import slots as slot_ops
from repro.serving.request import Request, RequestResult

Array = jax.Array


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


class _Slot:
    """Host-side record of the sequence occupying one pool row.

    A slot is *prefilling* while ``cursor < len(req.prompt)`` — its
    attention state lives in staging-pool row i and it takes no part in
    decode. Once the last chunk lands the staged row is committed into
    the pool and the slot decodes.
    """

    __slots__ = ("req", "result", "budget", "cursor")

    def __init__(self, req: Request, result: RequestResult, budget: int):
        self.req = req
        self.result = result
        self.budget = budget
        self.cursor = 0


class ServingEngine:
    """Continuous-batching generation over a fixed slot pool.

    Typical use::

        eng = ServingEngine(params, cfg, max_slots=8, max_len=512,
                            chunk_tokens=64)
        eng.submit(Request(prompt=[...], max_new_tokens=64))
        results = eng.run()

    or drive it step-by-step (one batched prefill chunk + one batched
    decode per ``step()``) and ``submit`` more requests while others are
    mid-decode.

    ``prefill_rows`` caps how many staged admissions share the packed
    prefill call (None = all staged, i.e. up to ``max_slots``; 1 =
    the serial one-admission-per-step schedule of the pre-batching
    engine). ``bucket_prefill`` pads packed chunk lengths up to powers
    of two to bound recompiles; disable it for bit-exact parity with
    the serial unpadded schedule at P=1. ``mesh`` shards the slot and
    staging pools per ``serve_state_specs`` (see module docstring).
    """

    def __init__(self, params, cfg: lm.ModelConfig, *, max_slots: int = 4,
                 max_len: int = 256, chunk_tokens: Optional[int] = None,
                 seed: int = 0, mesh=None,
                 prefill_rows: Optional[int] = None,
                 bucket_prefill: bool = True):
        if cfg.modality != "text":
            raise ValueError("serving engine drives text decode only")
        if chunk_tokens is not None and chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1")
        if prefill_rows is not None and prefill_rows < 1:
            raise ValueError("prefill_rows must be >= 1 (None = no cap)")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.chunk_tokens = chunk_tokens
        self.prefill_rows = prefill_rows
        self.bucket_prefill = bucket_prefill
        self.mesh = mesh
        # homogeneous configs stack all L layer states along one leading
        # axis so the jitted steps scan ONE compiled layer body
        # (lm.can_stack_layers); heterogeneous patterns keep the
        # per-unit layout
        self._stacked = lm.can_stack_layers(cfg)
        self.pool = lm.init_serve_state(cfg, b=max_slots, max_len=max_len,
                                        per_slot=True,
                                        stacked=self._stacked)
        # fixed-size staging pool: row i holds the partial prefill state
        # of the admission reserved on slot i (same pytree as the pool)
        self.staging = lm.init_serve_state(cfg, b=max_slots,
                                           max_len=max_len, per_slot=True,
                                           stacked=self._stacked)
        # immutable one-row template scattered at admission; every
        # prefill chain starts from this fresh per-slot row
        self._fresh_row = lm.init_serve_state(cfg, b=1, max_len=max_len,
                                              per_slot=True,
                                              stacked=self._stacked)
        # precomposed per-layer serve projections (A = (W M)^T): the
        # M·Wᵀ composition happens HERE, once at engine build — the
        # fused decode megakernel then does a single x @ A per token,
        # and the SAME pytree feeds the packed-prefill step so batched
        # ragged admission runs the fused prefill megakernel too
        self._decode_proj = lm.build_decode_proj(params, cfg,
                                                 stacked=self._stacked)
        # which implementation the jitted steps compiled — surfaced in
        # ``stats`` so bench runs can assert they measured the path
        # they claim (fused_kernel / jnp / exact / none)
        self._serve_paths = self._resolve_serve_paths()
        # likewise the layer-stacked param tree: interleaved once here
        # (a no-copy alias for the k=1 patterns) so the jitted steps
        # never re-stack weights per token
        self._step_params = params
        if self._stacked:
            self._step_params = dict(params)
            self._step_params["layers"] = lm.stack_layer_params(params,
                                                                cfg)

        pool_shardings = None
        if mesh is not None:
            from repro.parallel import serve_state_specs, make_shardings
            pool_shardings = make_shardings(
                serve_state_specs(self.pool, mesh), mesh)
            self.pool = jax.device_put(self.pool, pool_shardings)
            self.staging = jax.device_put(self.staging, pool_shardings)

        self._slots: list[Optional[_Slot]] = [None] * max_slots
        self._active = np.zeros(max_slots, bool)
        self._temps = np.zeros(max_slots, np.float32)
        self._top_ks = np.zeros(max_slots, np.int32)
        self._top_ps = np.ones(max_slots, np.float32)
        self._toks = np.zeros(max_slots, np.int32)
        self._prefill_order: list[int] = []    # slot idx, admission FIFO
        self._queue: list[Request] = []        # sorted by arrival_time
        self._key = jax.random.PRNGKey(seed)
        self._step_count = 0
        self._t0: Optional[float] = None
        self._ttfts: list[float] = []
        self._stats = {"decode_steps": 0, "decode_slot_steps": 0,
                       "prefill_tokens": 0, "prefill_chunks": 0,
                       "prefill_calls": 0, "prefill_padded_tokens": 0,
                       "prefill_rows_max": 0,
                       "max_prefill_tokens_per_step": 0,
                       "emitted_tokens": 0, "admitted": 0, "finished": 0}

        cfg_ = cfg  # closed over by the jitted steps

        def _constrain(tree):
            if pool_shardings is None:
                return tree
            return jax.lax.with_sharding_constraint(tree, pool_shardings)

        def _decode(params, proj, pool, toks, active, all_active):
            logits, new = lm.decode_step(params, cfg_, toks, pool,
                                         proj=proj)
            new = slot_ops.freeze_inactive(pool, new, active,
                                           all_active=all_active)
            return logits, _constrain(new)

        def _prefill(params, proj, staging, toks, idx, valid_len):
            # gather the P staged rows, advance them over one padded
            # (P, L) chunk, scatter them back — ONE device program per
            # step regardless of how many admissions are in flight;
            # with the precomposed proj the chunk runs the fused
            # prf_fused_prefill megakernel (one pallas_call per layer)
            sub = slot_ops.read_slots(staging, idx)
            logits, new = lm.prefill_chunk(params, cfg_, {"tokens": toks},
                                           sub, valid_len=valid_len,
                                           proj=proj)
            return logits, _constrain(slot_ops.write_slots(staging, new,
                                                           idx))

        def _commit(pool, staging, idx):
            # finished admissions: copy staged rows into the slot pool
            rows = slot_ops.read_slots(staging, idx)
            return _constrain(slot_ops.write_slots(pool, rows, idx))

        def _reset(staging, fresh, idx):
            # one scatter resets every slot admitted this step: the
            # one-row fresh template is broadcast along the slot axis
            k = idx.shape[0]
            fresh_k = slot_ops.tree_slot_map(
                lambda p, axis: jnp.repeat(p, k, axis=axis), fresh)
            return _constrain(slot_ops.write_slots(staging, fresh_k, idx))

        def _sample_plain(key, logits, temps):
            # greedy / plain-temperature rows only: skips the two
            # full-vocab sorts of the top-k/p masks on the hot loop
            greedy = jnp.argmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
            drawn = jax.random.categorical(key, scaled, axis=-1)
            return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)

        def _sample(key, logits, temps, top_ks, top_ps):
            v = logits.shape[-1]
            greedy = jnp.argmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
            # per-row top-k: drop logits below the k-th largest
            # (top_k <= 0 disables; the mask is then all-True)
            desc = jnp.sort(scaled, axis=-1)[:, ::-1]
            kidx = jnp.clip(jnp.where(top_ks > 0, top_ks, v) - 1, 0, v - 1)
            kth = jnp.take_along_axis(desc, kidx[:, None], axis=-1)
            masked = jnp.where(scaled >= kth, scaled, -jnp.inf)
            # per-row nucleus: keep the smallest prefix of probability
            # mass >= top_p (top_p >= 1 disables)
            probs = jax.nn.softmax(masked, axis=-1)
            sp = jnp.sort(probs, axis=-1)[:, ::-1]
            cum = jnp.cumsum(sp, axis=-1)
            keep = ((cum - sp) < top_ps[:, None]) | (top_ps[:, None] >= 1.0)
            cutoff = jnp.min(jnp.where(keep, sp, jnp.inf), axis=-1,
                             keepdims=True)
            masked = jnp.where(probs >= cutoff, masked, -jnp.inf)
            drawn = jax.random.categorical(key, masked, axis=-1)
            return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)

        self._decode_fn = jax.jit(_decode, donate_argnums=(2,),
                                  static_argnums=(5,))
        self._prefill_fn = jax.jit(_prefill, donate_argnums=(2,))
        self._commit_fn = jax.jit(_commit, donate_argnums=(0,))
        self._reset_fn = jax.jit(_reset, donate_argnums=(0,))
        self._sample_fn = jax.jit(_sample)
        self._sample_plain_fn = jax.jit(_sample_plain)

    # -- introspection ----------------------------------------------------

    def _resolve_serve_paths(self) -> dict:
        """Name the attention implementation each jitted step compiled:
        ``fused_kernel`` (the prf_fused_prefill / prf_fused_decode
        megakernels against the engine-precomposed projections — what
        ``cfg.use_kernel`` always selects here, since the engine builds
        the projections at construction; the two-stage kernel path is
        reachable only through the lm-level ``fused=False`` oracle
        entry points, never through the engine), ``jnp`` (pure-XLA
        reference), ``exact`` (softmax over per-slot KV pages — no
        Pallas path), or ``none`` (no attention blocks, e.g. pure-RWKV
        stacks)."""
        cfg = self.cfg
        if not any(k in ("attn", "local") for k in cfg.layer_kinds()):
            path = "none"
        elif cfg.attn.kind == "exact":
            path = "exact"
        elif self._decode_proj is not None:
            path = "fused_kernel"
        else:
            path = "jnp"
        return {"prefill_path": path, "decode_path": path}

    # -- clock ------------------------------------------------------------

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return time.monotonic() - self._t0

    # -- client API -------------------------------------------------------

    def submit(self, req: Union[Request, Sequence[int]], **kw) -> int:
        """Queue a request (or a bare token prompt). Returns its uid.

        Validates everything that would otherwise fail opaquely (or
        silently clamp) inside the jitted step functions: empty prompts,
        prompts that don't fit the per-slot ``max_len`` context budget
        alongside at least one generated token, out-of-vocab token ids,
        and degenerate sampling parameters.
        """
        if not isinstance(req, Request):
            req = Request(prompt=list(req), **kw)
        if len(req.prompt) == 0:
            raise ValueError("empty prompt: a request must carry at least "
                             "one prompt token")
        if len(req.prompt) + 1 > self.max_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} does not fit max_len "
                f"{self.max_len}: a slot's context page must hold the "
                f"prompt plus at least one generated token "
                f"(prompt <= max_len - 1 = {self.max_len - 1})")
        lo, hi = min(req.prompt), max(req.prompt)
        if lo < 0 or hi >= self.cfg.vocab:
            raise ValueError(
                f"prompt token ids must lie in the vocab range "
                f"[0, {self.cfg.vocab}) (got min={lo}, max={hi}); "
                f"out-of-range ids would be silently clamped by the "
                f"embedding gather inside jit")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (admission "
                             "always samples the first token)")
        if req.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if req.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if req.top_p <= 0:
            # top_p <= 0 would mask EVERY token to -inf and the row
            # would silently stream token 0
            raise ValueError("top_p must be > 0 (>= 1.0 disables)")
        bisect.insort(self._queue, req, key=lambda r: r.arrival_time)
        return req.uid

    def cancel(self, uid: int) -> Optional[RequestResult]:
        """Evict a queued, mid-prefill or mid-decode request. Returns its
        partial result (None if the uid is unknown)."""
        for i, req in enumerate(self._queue):
            if req.uid == uid:
                self._queue.pop(i)
                return RequestResult(uid=uid, prompt=list(req.prompt),
                                     arrival_time=req.arrival_time,
                                     cancelled=True)
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.req.uid == uid:
                res = slot.result
                res.cancelled = True
                res.finish_time = self._now()
                self._free(i)
                return res
        return None

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_prefilling(self) -> int:
        return len(self._prefill_order)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def next_arrival(self) -> Optional[float]:
        return self._queue[0].arrival_time if self._queue else None

    # -- scheduler --------------------------------------------------------

    def _free(self, i: int) -> None:
        self._slots[i] = None
        self._active[i] = False
        self._temps[i] = 0.0
        self._top_ks[i] = 0
        self._top_ps[i] = 1.0
        if i in self._prefill_order:
            self._prefill_order.remove(i)

    def _sample_one(self, req: Request, logits_row: Array) -> int:
        key = jax.random.fold_in(
            jax.random.fold_in(self._key, req.uid), self._step_count)
        temps = jnp.full((1,), req.temperature, jnp.float32)
        if req.top_k <= 0 and req.top_p >= 1.0:
            return int(self._sample_plain_fn(key, logits_row, temps)[0])
        return int(self._sample_fn(
            key, logits_row, temps,
            jnp.full((1,), req.top_k, jnp.int32),
            jnp.full((1,), req.top_p, jnp.float32))[0])

    def _admissions(self, now: float) -> None:
        """Reserve a free slot (prefill cursor 0, freshly reset staging
        row) for every arrived request, FIFO. The step's staging-row
        resets are batched into one scatter."""
        admitted: list[int] = []
        while self._queue and self._queue[0].arrival_time <= now:
            free = [i for i in range(self.max_slots)
                    if self._slots[i] is None]
            if not free:
                break
            req = self._queue.pop(0)
            result = RequestResult(uid=req.uid,
                                   prompt=list(map(int, req.prompt)),
                                   arrival_time=req.arrival_time)
            # exact-cache pages hold max_len keys: prompt + decoded tokens
            budget = min(req.max_new_tokens,
                         self.max_len - len(req.prompt))
            self._slots[free[0]] = _Slot(req, result, budget)
            admitted.append(free[0])
            self._prefill_order.append(free[0])
        if admitted:
            self.staging = self._reset_fn(
                self.staging, self._fresh_row,
                jnp.asarray(admitted, jnp.int32))

    def _plan_prefill(self) -> list[tuple[int, int]]:
        """Token-budget packer: split this step's prompt-token budget
        across the staged admissions, FIFO. Returns [(slot, tokens)].

        Blocking mode (``chunk_tokens=None``) grants every staged
        admission its full remaining prompt. Chunked + bucketed mode
        COALESCES: every staged row gets the same pow-2 grant
        ``g = prev_pow2(chunk_tokens // rows)``, so all non-tail rows
        land in one shared length bucket with ZERO padding waste —
        ``prefill_batch_occupancy`` is 1.0 under ragged admission
        bursts until the rows' last partial chunks. Unbucketed chunked
        mode keeps the legacy FIFO ceil-shares (the serial bit-exact
        contract at ``prefill_rows=1``). Either way at most
        ``chunk_tokens`` prompt tokens total run between two decode
        steps (the invariant the latency benchmark measures).
        """
        staged = self._prefill_order
        if self.prefill_rows is not None:
            staged = staged[:self.prefill_rows]
        grants: list[tuple[int, int]] = []
        if self.chunk_tokens is None:
            for i in staged:
                slot = self._slots[i]
                grants.append((i, len(slot.req.prompt) - slot.cursor))
            return grants
        budget = self.chunk_tokens
        if self.bucket_prefill and staged and budget >= len(staged):
            # coalesced equal-length grants: one bucket, no padding
            g = 1 << ((budget // len(staged)).bit_length() - 1)
            for i in staged:
                slot = self._slots[i]
                grants.append((i, min(len(slot.req.prompt) - slot.cursor,
                                      g)))
            return grants
        for j, i in enumerate(staged):
            if budget <= 0:
                break
            slot = self._slots[i]
            rem = len(slot.req.prompt) - slot.cursor
            share = -(-budget // (len(staged) - j))      # ceil division
            t = min(rem, share)
            grants.append((i, t))
            budget -= t
        return grants

    def _prefill_work(self) -> None:
        """Advance every scheduled admission by its granted chunk in ONE
        padded batched ``prefill_chunk`` call, then commit + activate the
        admissions whose prompts finished (also batched)."""
        grants = self._plan_prefill()
        if not grants:
            return
        ts = np.asarray([t for _, t in grants], np.int32)
        l_pad = int(ts.max())
        if self.bucket_prefill:
            l_pad = _next_pow2(l_pad)
        toks = np.zeros((len(grants), l_pad), np.int32)
        for r, (i, t) in enumerate(grants):
            slot = self._slots[i]
            toks[r, :t] = slot.req.prompt[slot.cursor:slot.cursor + t]
        # all-full rows take the legacy unpadded path (bit-exact with the
        # serial schedule); ragged rows carry per-row valid lengths
        vl = None if (ts == l_pad).all() else jnp.asarray(ts)
        idx = jnp.asarray([i for i, _ in grants], jnp.int32)
        logits, self.staging = self._prefill_fn(
            self._step_params, self._decode_proj, self.staging,
            jnp.asarray(toks), idx, vl)

        spent = int(ts.sum())
        self._stats["prefill_tokens"] += spent
        self._stats["prefill_chunks"] += len(grants)
        self._stats["prefill_calls"] += 1
        self._stats["prefill_padded_tokens"] += len(grants) * l_pad
        self._stats["prefill_rows_max"] = max(
            self._stats["prefill_rows_max"], len(grants))
        self._stats["max_prefill_tokens_per_step"] = max(
            self._stats["max_prefill_tokens_per_step"], spent)

        done: list[tuple[int, int]] = []
        for r, (i, t) in enumerate(grants):
            slot = self._slots[i]
            slot.cursor += t
            if slot.cursor == len(slot.req.prompt):
                done.append((r, i))
        if not done:
            return
        self.pool = self._commit_fn(
            self.pool, self.staging,
            jnp.asarray([i for _, i in done], jnp.int32))
        for r, i in done:
            self._prefill_order.remove(i)
            self._finish_admission(i, logits[r:r + 1])

    def _finish_admission(self, i: int, logits: Array) -> None:
        """Activate pool row i (already committed from staging)."""
        slot = self._slots[i]
        first = self._sample_one(slot.req, logits)
        now = self._now()
        slot.result.admit_time = now
        slot.result.tokens = [first]
        slot.result.token_times = [now]
        self._ttfts.append(now - slot.req.arrival_time)
        self._active[i] = True
        self._temps[i] = slot.req.temperature
        self._top_ks[i] = slot.req.top_k
        self._top_ps[i] = slot.req.top_p
        self._toks[i] = first
        self._stats["emitted_tokens"] += 1
        self._stats["admitted"] += 1

    # -- decode -----------------------------------------------------------

    def step(self) -> list[RequestResult]:
        """Admit what has arrived, run one batched prefill chunk over the
        staged admissions, one batched decode step over the active slots,
        and evict finished sequences. Returns newly finished results
        (possibly empty)."""
        finished: list[RequestResult] = []
        self._admissions(self._now())
        self._prefill_work()
        # admission may already exhaust a request (budget/eos on token 1)
        for i, slot in enumerate(self._slots):
            if slot is not None and self._active[i] and self._done(slot):
                finished.append(self._finish(i))
        if not self._active.any():
            return finished

        self._step_count += 1
        # static all-active flag: a fully occupied pool skips the
        # pool-wide freeze select (bit-identical either way)
        logits, self.pool = self._decode_fn(
            self._step_params, self._decode_proj, self.pool,
            jnp.asarray(self._toks), jnp.asarray(self._active),
            bool(self._active.all()))
        key = jax.random.fold_in(self._key, self._step_count)
        # host-side check: only pay the full-vocab sort/cumsum masks when
        # some active row actually uses top-k/p (the masks are identity
        # at the defaults, so both paths sample identically)
        if (self._top_ks > 0).any() or (self._top_ps < 1.0).any():
            toks = np.asarray(self._sample_fn(key, logits,
                                              jnp.asarray(self._temps),
                                              jnp.asarray(self._top_ks),
                                              jnp.asarray(self._top_ps)))
        else:
            toks = np.asarray(self._sample_plain_fn(
                key, logits, jnp.asarray(self._temps)))
        now = self._now()
        n_act = int(self._active.sum())
        self._stats["decode_steps"] += 1
        self._stats["decode_slot_steps"] += n_act
        for i in np.nonzero(self._active)[0]:
            slot = self._slots[i]
            tok = int(toks[i])
            slot.result.tokens.append(tok)
            slot.result.token_times.append(now)
            self._toks[i] = tok
            self._stats["emitted_tokens"] += 1
            if self._done(slot):
                finished.append(self._finish(i))
        return finished

    def _done(self, slot: _Slot) -> bool:
        toks = slot.result.tokens
        if len(toks) >= slot.budget:
            return True
        return slot.req.eos_id is not None and toks[-1] == slot.req.eos_id

    def _finish(self, i: int) -> RequestResult:
        res = self._slots[i].result
        res.finish_time = self._now()
        self._free(i)
        self._stats["finished"] += 1
        return res

    # -- batch runner -----------------------------------------------------

    def run(self, realtime: bool = False) -> list[RequestResult]:
        """Drive ``step()`` until queue and slots drain.

        ``realtime=True`` honors future ``arrival_time``s by sleeping
        while the pool is empty (Poisson-traffic benchmarking); otherwise
        arrival order is respected but waits are skipped.
        """
        results: list[RequestResult] = []
        while self.has_work:
            if (self.num_active == 0 and not self._prefill_order
                    and self._queue):
                wait = self._queue[0].arrival_time - self._now()
                if wait > 0:
                    if realtime:
                        time.sleep(wait)
                    else:
                        self._t0 -= wait       # jump the clock forward
            results.extend(self.step())
        return results

    # -- metrics ----------------------------------------------------------

    @property
    def stats(self) -> dict:
        s = dict(self._stats)
        s.update(self._serve_paths)
        steps = max(s["decode_steps"], 1)
        # fraction of slot-steps that carried a live sequence
        s["mean_occupancy"] = (s["decode_slot_steps"]
                               / (steps * self.max_slots))
        # fraction of the padded (P x L) prefill compute spent on real
        # prompt tokens, and how many admissions each call advanced
        s["prefill_batch_occupancy"] = (
            s["prefill_tokens"] / s["prefill_padded_tokens"]
            if s["prefill_padded_tokens"] else 1.0)
        s["prefill_rows_per_call"] = (
            s["prefill_chunks"] / s["prefill_calls"]
            if s["prefill_calls"] else 0.0)
        if self._ttfts:
            s["ttft_p50"] = float(np.percentile(self._ttfts, 50))
            s["ttft_p99"] = float(np.percentile(self._ttfts, 99))
        return s
