"""Continuous-batching serving engine over the O(1)-state PRF decode.

The paper's serving claim (docs/serving.md) is that PRF attention decodes
from a fixed-size running state — an (m x d_v) sum S, an (m,) normalizer
z and the running stabilizer max c per head — so a server can multiplex
many users over one batched decode step regardless of how long each
context is. The same state is what makes prefill *chunkable*: the state
after k prompt tokens is a valid resume point (``lm.prefill_chunk``), so
prompt work can be cut into budgeted slices instead of monopolizing the
device. This engine is that multiplexer:

  * a FIFO **request queue** with arrival times (Poisson traffic plugs in
    here — see benchmarks/serve_latency.py);
  * a device-resident **slot pool**: one serve-state pytree with
    ``max_slots`` batch rows, per-slot positions and (for the exact
    fallback) per-slot KV write indices (repro/serving/slots.py);
  * a **token-budgeted scheduler**: each ``step()`` spends at most
    ``chunk_tokens`` prompt tokens on ONE admission's next chunk (the
    admission keeps a per-slot prefill cursor and an off-pool staging
    state), then runs one batched decode step for all active slots — so
    a long prompt is amortized across decode steps instead of stalling
    them. ``chunk_tokens=None`` is the blocking baseline: whole prompts
    are prefilled at admission (the degenerate one-chunk schedule);
  * one jitted **batched decode step** that advances all slots in
    lock-step; inactive slots are masked so their state stays bit-frozen.
    A mid-prefill slot's state lives OFF the pool until its last chunk
    lands, so partial prefills never perturb pool rows.

Numerical contract: slot rows are computed elementwise over the batch
axis, so a sequence decoded inside a busy heterogeneous batch produces
bit-identical f32 logits to the same sequence decoded alone with
``lm.prefill`` + ``lm.decode_step`` (tests/test_serving_engine.py
asserts this for darkformer, performer and exact kernels). Chunking a
prompt changes the k-stabilizer trajectory (a running max instead of one
whole-prompt max), so chunked admission matches blocking admission to
f32 rounding — and bit-exactly when ``chunk_tokens >= prompt_len``
(tests/test_chunked_prefill.py).

Prefill compiles once per distinct chunk length, so ``chunk_tokens=N``
also caps compiles at one per residual length < N plus the full chunk.

Sampling: per-request ``temperature`` / ``top_k`` / ``top_p`` are applied
inside one jitted batched sample step; the defaults (0 / 0 / 1.0) leave
the greedy path bit-identical to plain argmax.
"""
from __future__ import annotations

import bisect
import time
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving import slots as slot_ops
from repro.serving.request import Request, RequestResult

Array = jax.Array


class _Slot:
    """Host-side record of the sequence occupying one pool row.

    A slot is *prefilling* while ``cursor < len(req.prompt)`` — its
    attention state is the off-pool B=1 ``state`` pytree and it takes no
    part in decode. Once the last chunk lands the state is scattered
    into the pool, ``state`` drops to None and the slot decodes.
    """

    __slots__ = ("req", "result", "budget", "cursor", "state")

    def __init__(self, req: Request, result: RequestResult, budget: int,
                 state):
        self.req = req
        self.result = result
        self.budget = budget
        self.cursor = 0
        self.state = state


class ServingEngine:
    """Continuous-batching generation over a fixed slot pool.

    Typical use::

        eng = ServingEngine(params, cfg, max_slots=8, max_len=512,
                            chunk_tokens=64)
        eng.submit(Request(prompt=[...], max_new_tokens=64))
        results = eng.run()

    or drive it step-by-step (one prefill chunk + one batched decode per
    ``step()``) and ``submit`` more requests while others are mid-decode.
    """

    def __init__(self, params, cfg: lm.ModelConfig, *, max_slots: int = 4,
                 max_len: int = 256, chunk_tokens: Optional[int] = None,
                 seed: int = 0):
        if cfg.modality != "text":
            raise ValueError("serving engine drives text decode only")
        if chunk_tokens is not None and chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.chunk_tokens = chunk_tokens
        self.pool = lm.init_serve_state(cfg, b=max_slots, max_len=max_len,
                                        per_slot=True)
        # immutable template scattered per admission; every prefill chain
        # starts from this fresh B=1 state
        self._fresh = lm.init_serve_state(cfg, b=1, max_len=max_len)

        self._slots: list[Optional[_Slot]] = [None] * max_slots
        self._active = np.zeros(max_slots, bool)
        self._temps = np.zeros(max_slots, np.float32)
        self._top_ks = np.zeros(max_slots, np.int32)
        self._top_ps = np.ones(max_slots, np.float32)
        self._toks = np.zeros(max_slots, np.int32)
        self._prefill_order: list[int] = []    # slot idx, admission FIFO
        self._queue: list[Request] = []        # sorted by arrival_time
        self._key = jax.random.PRNGKey(seed)
        self._step_count = 0
        self._t0: Optional[float] = None
        self._stats = {"decode_steps": 0, "decode_slot_steps": 0,
                       "prefill_tokens": 0, "prefill_chunks": 0,
                       "max_prefill_tokens_per_step": 0,
                       "emitted_tokens": 0, "admitted": 0, "finished": 0}

        cfg_ = cfg  # closed over by the jitted steps

        def _decode(params, pool, toks, active):
            logits, new = lm.decode_step(params, cfg_, toks, pool)
            return logits, slot_ops.freeze_inactive(pool, new, active)

        def _prefill_chunk(params, tokens, state):
            # (1, V) last-chunk-position logits + advanced B=1 state
            return lm.prefill_chunk(params, cfg_, {"tokens": tokens},
                                    state)

        def _write(pool, st, idx):
            return slot_ops.write_slot(pool, st, idx)

        def _sample_plain(key, logits, temps):
            # greedy / plain-temperature rows only: skips the two
            # full-vocab sorts of the top-k/p masks on the hot loop
            greedy = jnp.argmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
            drawn = jax.random.categorical(key, scaled, axis=-1)
            return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)

        def _sample(key, logits, temps, top_ks, top_ps):
            v = logits.shape[-1]
            greedy = jnp.argmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
            # per-row top-k: drop logits below the k-th largest
            # (top_k <= 0 disables; the mask is then all-True)
            desc = jnp.sort(scaled, axis=-1)[:, ::-1]
            kidx = jnp.clip(jnp.where(top_ks > 0, top_ks, v) - 1, 0, v - 1)
            kth = jnp.take_along_axis(desc, kidx[:, None], axis=-1)
            masked = jnp.where(scaled >= kth, scaled, -jnp.inf)
            # per-row nucleus: keep the smallest prefix of probability
            # mass >= top_p (top_p >= 1 disables)
            probs = jax.nn.softmax(masked, axis=-1)
            sp = jnp.sort(probs, axis=-1)[:, ::-1]
            cum = jnp.cumsum(sp, axis=-1)
            keep = ((cum - sp) < top_ps[:, None]) | (top_ps[:, None] >= 1.0)
            cutoff = jnp.min(jnp.where(keep, sp, jnp.inf), axis=-1,
                             keepdims=True)
            masked = jnp.where(probs >= cutoff, masked, -jnp.inf)
            drawn = jax.random.categorical(key, masked, axis=-1)
            return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)

        self._decode_fn = jax.jit(_decode, donate_argnums=(1,))
        self._write_fn = jax.jit(_write, donate_argnums=(0,))
        self._sample_fn = jax.jit(_sample)
        self._sample_plain_fn = jax.jit(_sample_plain)
        # one jit wrapper; XLA caches one executable per chunk length
        # (chunk_tokens caps the number of distinct lengths)
        self._prefill_chunk_fn = jax.jit(_prefill_chunk)

    # -- clock ------------------------------------------------------------

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return time.monotonic() - self._t0

    # -- client API -------------------------------------------------------

    def submit(self, req: Union[Request, Sequence[int]], **kw) -> int:
        """Queue a request (or a bare token prompt). Returns its uid."""
        if not isinstance(req, Request):
            req = Request(prompt=list(req), **kw)
        if len(req.prompt) == 0:
            raise ValueError("empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(req.prompt)} >= max_len {self.max_len}")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (admission "
                             "always samples the first token)")
        if req.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if req.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if req.top_p <= 0:
            # top_p <= 0 would mask EVERY token to -inf and the row
            # would silently stream token 0
            raise ValueError("top_p must be > 0 (>= 1.0 disables)")
        bisect.insort(self._queue, req, key=lambda r: r.arrival_time)
        return req.uid

    def cancel(self, uid: int) -> Optional[RequestResult]:
        """Evict a queued, mid-prefill or mid-decode request. Returns its
        partial result (None if the uid is unknown)."""
        for i, req in enumerate(self._queue):
            if req.uid == uid:
                self._queue.pop(i)
                return RequestResult(uid=uid, prompt=list(req.prompt),
                                     arrival_time=req.arrival_time,
                                     cancelled=True)
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.req.uid == uid:
                res = slot.result
                res.cancelled = True
                res.finish_time = self._now()
                self._free(i)
                return res
        return None

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_prefilling(self) -> int:
        return len(self._prefill_order)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def next_arrival(self) -> Optional[float]:
        return self._queue[0].arrival_time if self._queue else None

    # -- scheduler --------------------------------------------------------

    def _free(self, i: int) -> None:
        self._slots[i] = None
        self._active[i] = False
        self._temps[i] = 0.0
        self._top_ks[i] = 0
        self._top_ps[i] = 1.0
        if i in self._prefill_order:
            self._prefill_order.remove(i)

    def _sample_one(self, req: Request, logits_row: Array) -> int:
        key = jax.random.fold_in(
            jax.random.fold_in(self._key, req.uid), self._step_count)
        temps = jnp.full((1,), req.temperature, jnp.float32)
        if req.top_k <= 0 and req.top_p >= 1.0:
            return int(self._sample_plain_fn(key, logits_row, temps)[0])
        return int(self._sample_fn(
            key, logits_row, temps,
            jnp.full((1,), req.top_k, jnp.int32),
            jnp.full((1,), req.top_p, jnp.float32))[0])

    def _admissions(self, now: float) -> None:
        """Reserve a free slot (prefill cursor 0, fresh staging state)
        for every arrived request, FIFO."""
        while self._queue and self._queue[0].arrival_time <= now:
            free = [i for i in range(self.max_slots)
                    if self._slots[i] is None]
            if not free:
                return
            req = self._queue.pop(0)
            result = RequestResult(uid=req.uid,
                                   prompt=list(map(int, req.prompt)),
                                   arrival_time=req.arrival_time)
            # exact-cache pages hold max_len keys: prompt + decoded tokens
            budget = min(req.max_new_tokens,
                         self.max_len - len(req.prompt))
            self._slots[free[0]] = _Slot(req, result, budget, self._fresh)
            self._prefill_order.append(free[0])

    def _advance_prefill(self, i: int) -> Optional[Array]:
        """Run slot i's next prompt chunk. Returns the chunk's logits
        when the prompt is finished, else None."""
        slot = self._slots[i]
        prompt = slot.req.prompt
        remaining = len(prompt) - slot.cursor
        t = (remaining if self.chunk_tokens is None
             else min(self.chunk_tokens, remaining))
        tok = jnp.asarray(
            np.asarray(prompt[slot.cursor:slot.cursor + t], np.int32)[None])
        logits, slot.state = self._prefill_chunk_fn(self.params, tok,
                                                    slot.state)
        slot.cursor += t
        self._stats["prefill_tokens"] += t
        self._stats["prefill_chunks"] += 1
        return logits if slot.cursor == len(prompt) else None

    def _finish_admission(self, i: int, logits: Array) -> None:
        """Scatter the staged state into pool row i and activate it."""
        slot = self._slots[i]
        self.pool = self._write_fn(self.pool, slot.state, jnp.int32(i))
        slot.state = None
        first = self._sample_one(slot.req, logits)
        now = self._now()
        slot.result.admit_time = now
        slot.result.tokens = [first]
        slot.result.token_times = [now]
        self._active[i] = True
        self._temps[i] = slot.req.temperature
        self._top_ks[i] = slot.req.top_k
        self._top_ps[i] = slot.req.top_p
        self._toks[i] = first
        self._stats["emitted_tokens"] += 1
        self._stats["admitted"] += 1

    def _prefill_work(self) -> None:
        """Spend this step's prefill budget.

        Chunked (``chunk_tokens=N``): at most one chunk (<= N prompt
        tokens) of the oldest mid-prefill admission — the invariant the
        latency benchmark measures is that no more than N prompt tokens
        ever run between consecutive batched decode steps. Blocking
        (``chunk_tokens=None``): every pending admission prefills its
        whole prompt now.
        """
        spent = 0
        while self._prefill_order:
            i = self._prefill_order[0]
            before = self._slots[i].cursor
            logits = self._advance_prefill(i)
            spent += self._slots[i].cursor - before
            if logits is not None:
                self._prefill_order.pop(0)
                self._finish_admission(i, logits)
            if self.chunk_tokens is not None:
                break                      # one chunk per step, at most
        self._stats["max_prefill_tokens_per_step"] = max(
            self._stats["max_prefill_tokens_per_step"], spent)

    # -- decode -----------------------------------------------------------

    def step(self) -> list[RequestResult]:
        """Admit what has arrived, run one prompt chunk (if an admission
        is mid-prefill), one batched decode step over the active slots,
        and evict finished sequences. Returns newly finished results
        (possibly empty)."""
        finished: list[RequestResult] = []
        self._admissions(self._now())
        self._prefill_work()
        # admission may already exhaust a request (budget/eos on token 1)
        for i, slot in enumerate(self._slots):
            if slot is not None and self._active[i] and self._done(slot):
                finished.append(self._finish(i))
        if not self._active.any():
            return finished

        self._step_count += 1
        logits, self.pool = self._decode_fn(
            self.params, self.pool, jnp.asarray(self._toks),
            jnp.asarray(self._active))
        key = jax.random.fold_in(self._key, self._step_count)
        # host-side check: only pay the full-vocab sort/cumsum masks when
        # some active row actually uses top-k/p (the masks are identity
        # at the defaults, so both paths sample identically)
        if (self._top_ks > 0).any() or (self._top_ps < 1.0).any():
            toks = np.asarray(self._sample_fn(key, logits,
                                              jnp.asarray(self._temps),
                                              jnp.asarray(self._top_ks),
                                              jnp.asarray(self._top_ps)))
        else:
            toks = np.asarray(self._sample_plain_fn(
                key, logits, jnp.asarray(self._temps)))
        now = self._now()
        n_act = int(self._active.sum())
        self._stats["decode_steps"] += 1
        self._stats["decode_slot_steps"] += n_act
        for i in np.nonzero(self._active)[0]:
            slot = self._slots[i]
            tok = int(toks[i])
            slot.result.tokens.append(tok)
            slot.result.token_times.append(now)
            self._toks[i] = tok
            self._stats["emitted_tokens"] += 1
            if self._done(slot):
                finished.append(self._finish(i))
        return finished

    def _done(self, slot: _Slot) -> bool:
        toks = slot.result.tokens
        if len(toks) >= slot.budget:
            return True
        return slot.req.eos_id is not None and toks[-1] == slot.req.eos_id

    def _finish(self, i: int) -> RequestResult:
        res = self._slots[i].result
        res.finish_time = self._now()
        self._free(i)
        self._stats["finished"] += 1
        return res

    # -- batch runner -----------------------------------------------------

    def run(self, realtime: bool = False) -> list[RequestResult]:
        """Drive ``step()`` until queue and slots drain.

        ``realtime=True`` honors future ``arrival_time``s by sleeping
        while the pool is empty (Poisson-traffic benchmarking); otherwise
        arrival order is respected but waits are skipped.
        """
        results: list[RequestResult] = []
        while self.has_work:
            if (self.num_active == 0 and not self._prefill_order
                    and self._queue):
                wait = self._queue[0].arrival_time - self._now()
                if wait > 0:
                    if realtime:
                        time.sleep(wait)
                    else:
                        self._t0 -= wait       # jump the clock forward
            results.extend(self.step())
        return results

    # -- metrics ----------------------------------------------------------

    @property
    def stats(self) -> dict:
        s = dict(self._stats)
        steps = max(s["decode_steps"], 1)
        # fraction of slot-steps that carried a live sequence
        s["mean_occupancy"] = (s["decode_slot_steps"]
                               / (steps * self.max_slots))
        return s
