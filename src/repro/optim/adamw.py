"""AdamW with decoupled weight decay + global-norm clipping.

Moments are kept in f32 regardless of param dtype (bf16 training keeps
master statistics in f32; the update is computed in f32 and cast back).
``factored_second_moment`` switches v to Adafactor-style row/col factors for
matrices — an optional memory saver for the 235B config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    factored_second_moment: bool = False


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> tuple[PyTree,
                                                                Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), \
        norm


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adamw_init(params: PyTree, cfg: AdamWConfig) -> dict:
    def zeros_like_f32(p):
        return jnp.zeros(p.shape, jnp.float32)

    if cfg.factored_second_moment:
        def v_init(p):
            if _factored(p.shape):
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                         jnp.float32)}
            return zeros_like_f32(p)
        v = jax.tree_util.tree_map(v_init, params)
    else:
        v = jax.tree_util.tree_map(zeros_like_f32, params)
    return {"mu": jax.tree_util.tree_map(zeros_like_f32, params),
            "nu": v,
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(params: PyTree, grads: PyTree, state: dict,
                 cfg: AdamWConfig, lr: Array) -> tuple[PyTree, dict, dict]:
    """One step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd_mu(mu, g):
        return cfg.b1 * mu + (1.0 - cfg.b1) * g.astype(jnp.float32)

    new_mu = jax.tree_util.tree_map(upd_mu, state["mu"], grads)

    if cfg.factored_second_moment:
        def upd_nu(nu, g):
            g2 = jnp.square(g.astype(jnp.float32)) + 1e-30
            if isinstance(nu, dict):
                return {"row": cfg.b2 * nu["row"]
                        + (1 - cfg.b2) * jnp.mean(g2, axis=-1),
                        "col": cfg.b2 * nu["col"]
                        + (1 - cfg.b2) * jnp.mean(g2, axis=-2)}
            return cfg.b2 * nu + (1 - cfg.b2) * g2

        def nu_to_v(nu):
            if isinstance(nu, dict):
                r = nu["row"][..., :, None]
                c = nu["col"][..., None, :]
                denom = jnp.mean(nu["row"], axis=-1)[..., None, None] + 1e-30
                return r * c / denom
            return nu

        new_nu = jax.tree_util.tree_map(upd_nu, state["nu"], grads,
                                        is_leaf=lambda x: isinstance(x, dict)
                                        and "row" in x)
        v_eff = jax.tree_util.tree_map(nu_to_v, new_nu,
                                       is_leaf=lambda x: isinstance(x, dict)
                                       and "row" in x)
    else:
        def upd_nu(nu, g):
            return cfg.b2 * nu + (1 - cfg.b2) * jnp.square(
                g.astype(jnp.float32))
        new_nu = jax.tree_util.tree_map(upd_nu, state["nu"], grads)
        v_eff = new_nu

    def upd_p(p, mu, v):
        m_hat = mu / c1
        v_hat = v / c2
        step = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd_p, params, new_mu, v_eff)
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    return new_params, new_state, {"grad_norm": gnorm,
                                   "lr": jnp.asarray(lr, jnp.float32)}
