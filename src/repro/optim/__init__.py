"""Optimizers + schedules, from scratch (no optax in this container)."""
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               global_norm, clip_by_global_norm)
from repro.optim.schedules import (constant, cosine_warmup, linear_warmup)
