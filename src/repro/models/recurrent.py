"""Recurrent mixers: RG-LRU (Griffin / RecurrentGemma) and RWKV-6.

Both are attention-free sequence mixers with O(1) decode state; they fill
the `rec` / `rwkv` slots in hybrid block patterns. The paper's PRF technique
does not apply to them (no softmax kernel) — see DESIGN §Arch-applicability.

RG-LRU (arXiv:2402.19427):
    x, g = W_x u, W_g u                  (both d_rnn)
    x <- causal depthwise conv1d(x, k=4)
    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_i x_t)
    log a_t = -c * softplus(lam) * r_t          (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)     [associative scan]
    out = W_o (h * gelu(g))

RWKV-6 "Finch" (arXiv:2404.05892): token-shift lerp + data-dependent decay
    w_t = exp(-exp(lam_w + tanh(x_t A) B)), per-head wkv state S (dh x dh):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  o_t = r_t (S_{t-1} + diag(u) k v^T)
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as ll

Array = jax.Array

_RGLRU_C = 8.0
_CONV_K = 4


# ---------------------------------------------------------------------------
# RG-LRU block
# ---------------------------------------------------------------------------

class RGLRUState(NamedTuple):
    h: Array          # (B, d_rnn) f32
    conv: Array       # (B, CONV_K-1, d_rnn) — trailing inputs for the conv


def rglru_init(key, d_model: int, d_rnn: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    # lam init so that a^c*softplus in (0.9, 0.999) roughly (Griffin A.2).
    u = jax.random.uniform(ks[5], (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RGLRU_C))
    return {
        "wx": ll.trunc_normal(ks[0], (d_model, d_rnn), 1.0, dtype),
        "wg": ll.trunc_normal(ks[1], (d_model, d_rnn), 1.0, dtype),
        "conv_w": ll.trunc_normal(ks[2], (_CONV_K, d_rnn), float(_CONV_K),
                                  dtype),
        "wa": ll.trunc_normal(ks[3], (d_rnn, d_rnn), 1.0, dtype),
        "wi": ll.trunc_normal(ks[4], (d_rnn, d_rnn), 1.0, dtype),
        "lam": lam,
        "wo": ll.trunc_normal(ks[1], (d_rnn, d_model), 1.0, dtype),
    }


def _causal_conv(x: Array, w: Array, prefix: Optional[Array] = None):
    """Depthwise causal conv. x: (B, L, d); w: (K, d); prefix: (B,K-1,d)."""
    b, l, d = x.shape
    if prefix is None:
        prefix = jnp.zeros((b, _CONV_K - 1, d), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(_CONV_K):
        out = out + xp[:, i:i + l].astype(jnp.float32) * w[i].astype(
            jnp.float32)
    return out.astype(x.dtype), xp[:, -(_CONV_K - 1):]


def _rglru_scan(x: Array, a: Array, i_gate: Array, h0: Array):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t) via associative scan."""
    a = a.astype(jnp.float32)
    inp = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0)) * (
        i_gate.astype(jnp.float32) * x.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_seq = jnp.concatenate([jnp.ones_like(a[:, :1]) if h0 is None else
                             jnp.ones_like(a[:, :1]), a], axis=1)
    b_seq = jnp.concatenate([h0[:, None].astype(jnp.float32), inp], axis=1)
    _, hs = jax.lax.associative_scan(combine, (a_seq, b_seq), axis=1)
    return hs[:, 1:], hs[:, -1]


def rglru_apply(params: dict, u: Array,
                state: Optional[RGLRUState] = None,
                valid_len: Optional[Array] = None
                ) -> tuple[Array, RGLRUState]:
    """u: (B, L, d_model) -> (out, new_state).

    ``valid_len`` ((B,) int32) marks ragged rows of a padded chunk: at
    padded steps the gate is forced to a=1 (so the input branch
    sqrt(1-a^2)=0 vanishes and h carries through unchanged), and the conv
    tail is gathered per row at its last valid window — the carry equals
    the one an unpadded run over ``valid_len[b]`` tokens would produce."""
    x = u @ params["wx"]
    g = u @ params["wg"]
    prefix = None if state is None else state.conv
    x_pre = x                                  # pre-conv inputs: the conv
    x, conv_tail = _causal_conv(x, params["conv_w"], prefix)  # tail holds
    xf = x.astype(jnp.float32)                 # these, not conv outputs
    r = jax.nn.sigmoid(xf @ params["wa"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(xf @ params["wi"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b, l = u.shape[0], u.shape[1]
    if valid_len is not None:
        vmask = (jnp.arange(l)[None] < valid_len[:, None])[..., None]
        a = jnp.where(vmask, a, 1.0)        # freeze h past the valid end
    h0 = (jnp.zeros((b, x.shape[-1]), jnp.float32) if state is None
          else state.h)
    hs, h_last = _rglru_scan(x, a, i_gate, h0)
    if valid_len is not None:
        # conv_tail from _causal_conv is xp[:, L:L+K-1]; re-gather each
        # row's window ending at its own valid length instead
        xp = jnp.concatenate([jnp.zeros((b, _CONV_K - 1, x_pre.shape[-1]),
                                        x_pre.dtype) if prefix is None
                              else prefix.astype(x_pre.dtype), x_pre],
                             axis=1)
        conv_tail = jax.vmap(
            lambda row, t: jax.lax.dynamic_slice_in_dim(
                row, t, _CONV_K - 1, axis=0))(xp, valid_len)
        h_last = jnp.take_along_axis(
            hs, jnp.maximum(valid_len - 1, 0)[:, None, None], axis=1)[:, 0]
    out = (hs * jax.nn.gelu(g.astype(jnp.float32))).astype(u.dtype)
    return out @ params["wo"], RGLRUState(h=h_last.astype(jnp.float32),
                                          conv=conv_tail)


def rglru_decode(params: dict, u: Array, state: RGLRUState
                 ) -> tuple[Array, RGLRUState]:
    """Single-token step. u: (B, 1, d_model)."""
    return rglru_apply(params, u, state)


def init_rglru_state(b: int, d_rnn: int) -> RGLRUState:
    return RGLRUState(h=jnp.zeros((b, d_rnn), jnp.float32),
                      conv=jnp.zeros((b, _CONV_K - 1, d_rnn), jnp.float32))


# ---------------------------------------------------------------------------
# RWKV-6 block (time mix; the channel mix lives in lm.py as the "ffn")
# ---------------------------------------------------------------------------

class RWKVState(NamedTuple):
    s: Array          # (B, H, dh, dh) f32 wkv state
    shift: Array      # (B, d_model)   last token (time-mix token shift)


def rwkv6_init(key, d_model: int, n_heads: int, decay_rank: int = 64,
               dtype=jnp.float32) -> dict:
    dh = d_model // n_heads
    ks = jax.random.split(key, 10)
    return {
        "mu": 0.5 * jnp.ones((5, d_model), jnp.float32),   # r,k,v,w,g mixes
        "wr": ll.trunc_normal(ks[0], (d_model, d_model), 1.0, dtype),
        "wk": ll.trunc_normal(ks[1], (d_model, d_model), 1.0, dtype),
        "wv": ll.trunc_normal(ks[2], (d_model, d_model), 1.0, dtype),
        "wg": ll.trunc_normal(ks[3], (d_model, d_model), 1.0, dtype),
        "decay_a": ll.trunc_normal(ks[4], (d_model, decay_rank), 1.0,
                                   jnp.float32),
        "decay_b": ll.trunc_normal(ks[5], (decay_rank, d_model), 1.0,
                                   jnp.float32),
        "lam_w": jnp.zeros((d_model,), jnp.float32),
        "u": jnp.zeros((n_heads, dh), jnp.float32),        # bonus
        "ln_x": ll.layernorm_init(d_model),                # group-norm-ish
        "wo": ll.trunc_normal(ks[6], (d_model, d_model), 1.0, dtype),
    }


def _token_shift(x: Array, last: Array) -> Array:
    """x_{t-1} with x_{-1} = last. x: (B, L, d); last: (B, d)."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """r,k,v,w: (B, H, L, dh); u: (H, dh); s0: (B, H, dh, dh)."""
    def step(s, xs):
        r_t, k_t, v_t, w_t = xs            # (B, H, dh)
        kv = k_t[..., :, None] * v_t[..., None, :]
        o = jnp.einsum("bhd,bhde->bhe", r_t,
                       s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, o

    seq = tuple(jnp.moveaxis(t.astype(jnp.float32), 2, 0)
                for t in (r, k, v, w))
    s_last, outs = jax.lax.scan(step, s0.astype(jnp.float32), seq)
    return jnp.moveaxis(outs, 0, 2), s_last


def rwkv6_apply(params: dict, x: Array, n_heads: int,
                state: Optional[RWKVState] = None,
                valid_len: Optional[Array] = None
                ) -> tuple[Array, RWKVState]:
    """x: (B, L, d_model) -> (out, state).

    ``valid_len`` ((B,) int32) marks ragged rows of a padded chunk: at
    padded steps the decay is forced to w=1 and k to 0, so
    S = diag(1) S + 0 carries through unchanged, and the token-shift
    state is gathered at each row's last valid token."""
    b, l, d = x.shape
    dh = d // n_heads
    last = (jnp.zeros((b, d), x.dtype) if state is None
            else state.shift.astype(x.dtype))
    xprev = _token_shift(x, last)
    mu = params["mu"]

    def mix(i):
        return x + (xprev - x) * mu[i].astype(x.dtype)

    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ params["wr"]).reshape(b, l, n_heads, dh)
    k = (xk @ params["wk"]).reshape(b, l, n_heads, dh)
    v = (xv @ params["wv"]).reshape(b, l, n_heads, dh)
    g = xg @ params["wg"]
    # data-dependent decay (the "Finch" signature)
    dd = jnp.tanh(xw.astype(jnp.float32) @ params["decay_a"]) @ params[
        "decay_b"]
    w = jnp.exp(-jnp.exp(params["lam_w"] + dd))        # (B, L, d) in (0,1)
    w = w.reshape(b, l, n_heads, dh)
    if valid_len is not None:
        vmask = (jnp.arange(l)[None] < valid_len[:, None]
                 )[:, :, None, None]                   # (B, L, 1, 1)
        w = jnp.where(vmask, w, 1.0)
        k = jnp.where(vmask, k, 0.0)
    r, k, v, w = (jnp.moveaxis(t, 2, 1) for t in (r, k, v, w))  # (B,H,L,dh)
    s0 = (jnp.zeros((b, n_heads, dh, dh), jnp.float32) if state is None
          else state.s)
    o, s_last = _wkv_scan(r, k, v, w, params["u"], s0)
    o = jnp.moveaxis(o, 1, 2).reshape(b, l, d)
    o = ll.layernorm(params["ln_x"], o)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(o.dtype)
    out = o.astype(x.dtype) @ params["wo"]
    shift = (x[:, -1] if valid_len is None else
             jnp.take_along_axis(
                 x, jnp.maximum(valid_len - 1, 0)[:, None, None],
                 axis=1)[:, 0])
    return out, RWKVState(s=s_last, shift=shift.astype(jnp.float32))


def init_rwkv_state(b: int, d_model: int, n_heads: int) -> RWKVState:
    dh = d_model // n_heads
    return RWKVState(s=jnp.zeros((b, n_heads, dh, dh), jnp.float32),
                     shift=jnp.zeros((b, d_model), jnp.float32))


def rwkv6_channel_mix_init(key, d_model: int, d_ff: int,
                           dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d_model), jnp.float32),
        "wk": ll.trunc_normal(k1, (d_model, d_ff), 1.0, dtype),
        "wv": ll.trunc_normal(k2, (d_ff, d_model), 1.0, dtype),
        "wr": ll.trunc_normal(k3, (d_model, d_model), 1.0, dtype),
    }


def rwkv6_channel_mix(params: dict, x: Array,
                      last: Optional[Array] = None,
                      valid_len: Optional[Array] = None
                      ) -> tuple[Array, Array]:
    """RWKV channel mix: out = sigmoid(W_r xr) * (W_v relu(W_k xk)^2).

    ``valid_len`` ((B,) int32): the carried token-shift state is gathered
    at each row's last valid token instead of position L-1."""
    b, l, d = x.shape
    last = jnp.zeros((b, d), x.dtype) if last is None else last.astype(
        x.dtype)
    xprev = _token_shift(x, last)
    mu = params["mu"]
    xk = x + (xprev - x) * mu[0].astype(x.dtype)
    xr = x + (xprev - x) * mu[1].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    out = jax.nn.sigmoid((xr @ params["wr"]).astype(jnp.float32)).astype(
        x.dtype) * (k @ params["wv"])
    shift = (x[:, -1] if valid_len is None else
             jnp.take_along_axis(
                 x, jnp.maximum(valid_len - 1, 0)[:, None, None],
                 axis=1)[:, 0])
    return out, shift.astype(jnp.float32)
