"""Model substrate: layers, mixers, and the composable LM stack."""
from repro.models.lm import (ModelConfig, init_params, forward_train,
                             loss_fn, prefill, decode_step,
                             init_serve_state)
from repro.models.layers import MoEConfig
