"""Attention mixer block: projections + RoPE + (RF|exact) attention + serve.

This is where the paper's technique plugs into the transformer: the block
owns per-KV-group feature params ({"w", "m_mat"}) alongside q/k/v/o, and
dispatches on FeatureConfig.kind. GQA layout throughout:
  q -> (B, G, Hg, L, dh);  k, v -> (B, G, 1, L, dh).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import attention as rfa
from repro.core import feature_maps as fm
from repro.models import layers as ll

Array = jax.Array


def attn_init(key, d_model: int, n_heads: int, n_kv: int, d_head: int,
              cfg: fm.FeatureConfig, qk_norm: bool = False,
              dtype=jnp.float32) -> dict:
    kq, kk, kv, ko, kf = jax.random.split(key, 5)
    p = {
        "wq": ll.trunc_normal(kq, (d_model, n_heads * d_head), 1.0, dtype),
        "wk": ll.trunc_normal(kk, (d_model, n_kv * d_head), 1.0, dtype),
        "wv": ll.trunc_normal(kv, (d_model, n_kv * d_head), 1.0, dtype),
        "wo": ll.trunc_normal(ko, (n_heads * d_head, d_model), 1.0, dtype),
    }
    if cfg.kind in ("performer", "darkformer", "lfk"):
        p["feat"] = fm.init_feature_params(kf, cfg, d_head, n_groups=n_kv,
                                           dtype=jnp.float32)
    if qk_norm:
        p["q_norm"] = ll.rmsnorm_init(d_head, dtype)
        p["k_norm"] = ll.rmsnorm_init(d_head, dtype)
    return p


def _project(params, x, n_heads, n_kv, d_head, qk_norm, positions,
             rope_theta):
    b, l, _ = x.shape
    hg = n_heads // n_kv
    q = (x @ params["wq"]).reshape(b, l, n_kv, hg, d_head)
    k = (x @ params["wk"]).reshape(b, l, n_kv, 1, d_head)
    v = (x @ params["wv"]).reshape(b, l, n_kv, 1, d_head)
    q = jnp.moveaxis(q, 1, 3)          # (B, G, Hg, L, dh)
    k = jnp.moveaxis(k, 1, 3)
    v = jnp.moveaxis(v, 1, 3)
    if qk_norm:
        q = ll.rmsnorm(params["q_norm"], q)
        k = ll.rmsnorm(params["k_norm"], k)
    if rope_theta > 0:
        q = ll.apply_rope(q, positions, rope_theta)
        k = ll.apply_rope(k, positions, rope_theta)
    return q, k, v


def _merge_heads(out, params):
    # out: (B, G, Hg, L, dh) -> (B, L, H*dh) @ wo
    b, g, hg, l, dh = out.shape
    out = jnp.moveaxis(out, 3, 1).reshape(b, l, g * hg * dh)
    return out @ params["wo"]


def attn_apply(params: dict, x: Array, cfg: fm.FeatureConfig, *,
               n_heads: int, n_kv: int, d_head: int,
               causal: bool = True, window: Optional[int] = None,
               qk_norm: bool = False, rope_theta: float = 10000.0,
               positions: Optional[Array] = None,
               use_kernel: bool = False,
               baseline_key: Optional[Array] = None) -> Array:
    l = x.shape[1]
    if positions is None:
        positions = jnp.arange(l)
    q, k, v = _project(params, x, n_heads, n_kv, d_head, qk_norm,
                       positions, rope_theta)
    out = rfa.rf_attention(q, k, v, params.get("feat"), cfg, causal=causal,
                           window=window, use_kernel=use_kernel,
                           baseline_key=baseline_key)
    return _merge_heads(out, params)


def attn_prefill(params, x, cfg, *, n_heads, n_kv, d_head,
                 window=None, qk_norm=False, rope_theta=10000.0,
                 max_len=None, use_kernel=False, state=None,
                 position=None, valid_len=None, proj=None):
    """Prefill one prompt chunk. ``state=None`` + ``position=None`` is the
    legacy whole-prompt call; with an incoming serve ``state`` and a chunk
    start ``position`` (() int32, or (B,) per-slot starts) the pass
    resumes: RoPE rotates at absolute positions and the attention state
    advances from where the previous chunk left it. ``valid_len`` ((B,)
    int32) marks ragged rows in a padded multi-admission chunk — see
    ``rfa.rf_attention_prefill``. ``proj`` is the block's precomposed
    projection (``fm.precompose_projection``) selecting the fused
    prefill megakernel under ``use_kernel``."""
    l = x.shape[1]
    if position is None:
        positions = jnp.arange(l)
    elif position.ndim == 0:
        positions = position + jnp.arange(l)
    else:                      # (B,) per-row starts -> (B, 1, 1, L)
        b = x.shape[0]
        positions = (position[:, None]
                     + jnp.arange(l)[None]).reshape(b, 1, 1, l)
    q, k, v = _project(params, x, n_heads, n_kv, d_head, qk_norm,
                       positions, rope_theta)
    out, state = rfa.rf_attention_prefill(
        q, k, v, params.get("feat"), cfg, window=window,
        max_len=max_len, use_kernel=use_kernel, state=state,
        valid_len=valid_len, proj=proj)
    return _merge_heads(out, params), state


def attn_decode(params, x, state, cfg, *, n_heads, n_kv, d_head,
                position, window=None, qk_norm=False, rope_theta=10000.0,
                use_kernel=False, proj=None):
    """x: (B, 1, d_model); position: () int32 current index, or (B,)
    int32 per-slot positions (continuous batching — each slot RoPE-rotates
    by its own sequence position). ``proj`` is the block's precomposed
    decode projection (``fm.precompose_projection``) selecting the fused
    megakernel path under ``use_kernel``."""
    if position.ndim == 0:
        positions = position[None]                       # (1,) -> all rows
    else:
        positions = position.reshape(-1, 1, 1, 1)        # (B,1,1,1)
    q, k, v = _project(params, x, n_heads, n_kv, d_head, qk_norm,
                       positions, rope_theta)
    out, state = rfa.rf_attention_decode(q, k, v, state,
                                         params.get("feat"), cfg,
                                         window=window,
                                         use_kernel=use_kernel,
                                         proj=proj)
    return _merge_heads(out, params), state


def init_attn_serve_state(cfg: fm.FeatureConfig, b, n_heads, n_kv, d_head,
                          max_len, window=None,
                          per_slot=False) -> rfa.AttnServeState:
    """ShapeDtype-consistent initial serving state for one attention block.

    ``per_slot`` gives the exact-attention cache a (B,) length vector so
    each batch row (serving slot) tracks its own write index.
    """
    hg = n_heads // n_kv
    if cfg.kind == "exact":
        # NOTE: window mode could use a rolling buffer of size `window`;
        # we keep the full-length cache (decode writes at absolute idx).
        lmax = max_len
        return rfa.AttnServeState(
            kv_k=jnp.zeros((b, n_kv, lmax, d_head), jnp.float32),
            kv_v=jnp.zeros((b, n_kv, lmax, d_head), jnp.float32),
            length=jnp.zeros((b,) if per_slot else (), jnp.int32))
    return rfa.init_linear_serve_state(b, n_kv, hg, cfg.num_features,
                                       d_head)


def init_paged_attn_state(b: int, max_pages: int) -> rfa.AttnServeState:
    """Detached paged exact-KV serve state for one attention block: a
    per-row page table + write index, with ``kv_k``/``kv_v`` left None.
    The shared page pools live OUTSIDE the slot pool (they have no slot
    axis — see ``lm.init_kv_pages``) and are attached around each jitted
    step (``lm.attach_kv_pages``); the slot-pool ops in
    repro/serving/slots.py skip the None leaves."""
    return rfa.AttnServeState(
        length=jnp.zeros((b,), jnp.int32),
        table=jnp.zeros((b, max_pages), jnp.int32))
