"""Model substrate: norms, RoPE, MLPs, MoE — pure-JAX (no flax).

Every layer is an (init, apply) pair over explicit param pytrees. Weight
layouts are chosen so the sharding rules in repro/parallel/sharding.py can
match on dict key names (see LOGICAL_AXES there).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def trunc_normal(key, shape, scale, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = (scale / fan_in) ** 0.5
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                             jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}      # gemma-style (1 + scale)


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def apply_norm(kind: str, params: dict, x: Array) -> Array:
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


def norm_init(kind: str, d: int, dtype=jnp.float32) -> dict:
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(
        d, dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> Array:
    return theta ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., L, d_head); positions: (L,) or broadcastable int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (L, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, kind: str,
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_out": trunc_normal(k3, (d_ff, d_model), 1.0, dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = trunc_normal(k1, (d_model, d_ff), 1.0, dtype)
        p["w_up"] = trunc_normal(k2, (d_model, d_ff), 1.0, dtype)
    else:
        p["w_up"] = trunc_normal(k2, (d_model, d_ff), 1.0, dtype)
    return p


def mlp_apply(params: dict, x: Array, kind: str) -> Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-based einsum dispatch — GShard style)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # Optional (dp_axes, expert_axis) to pin the dispatch buffers: the
    # (B,E,C,d) scatter/gather buffers get batch on dp_axes and the expert
    # dim on expert_axis ("model" under EP, None under TP-expert
    # fallback). Prevents XLA SPMD from re-sharding them across 'model'
    # (shows up as huge all-reduces in the collective roofline term).
    # Requires an ambient mesh (jax.set_mesh) at trace time.
    dispatch_spec: Optional[tuple] = None


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, f = cfg.num_experts, cfg.d_ff
    return {
        "router": trunc_normal(k1, (d_model, e), 1.0, jnp.float32),
        "w_gate": trunc_normal(k2, (e, d_model, f), 1.0, dtype),
        "w_up": trunc_normal(k3, (e, d_model, f), 1.0, dtype),
        "w_out": trunc_normal(k4, (e, f, d_model), 1.0, dtype),
    }


def moe_apply(params: dict, x: Array, cfg: MoEConfig
              ) -> tuple[Array, Array]:
    """x: (B, L, d) -> (out, aux_loss). Capacity-dropped top-k routing.

    Dispatch/combine are one-hot einsums over a (B, E, C) capacity buffer so
    XLA SPMD can turn the expert axis into all-to-all under EP sharding.
    """
    b, l, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * k * l / e))
    logits = (x.astype(jnp.float32) @ params["router"])       # (B, L, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                  # (B, L, K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    onehot_top1 = jax.nn.one_hot(idx[..., 0], e)
    ce = jnp.mean(onehot_top1, axis=(0, 1))
    aux = cfg.aux_loss_weight * e * jnp.sum(me * ce)

    # Position of each (token, k) within its expert's capacity buffer
    # (GShard semantics: capacity group = batch row). Dispatch/combine are
    # scatter/gather (O(B L K d)) rather than one-hot einsums (O(B L E C d))
    # so neither compute nor memory scales with E*C; under EP sharding the
    # scatter across the expert axis lowers to the MoE all-to-all.
    sel = jax.nn.one_hot(idx, e, dtype=jnp.int32)             # (B, L, K, E)
    flat = sel.reshape(b, l * k, e)
    pos_e = jnp.cumsum(flat, axis=1) - 1                      # (B, L*K, E)
    pos = jnp.take_along_axis(
        pos_e.reshape(b, l, k, e), idx[..., None], axis=-1)[..., 0]
    in_cap = pos < cap                                        # (B, L, K)
    pos_c = jnp.clip(pos, 0, cap - 1)
    b_idx = jnp.arange(b)[:, None, None]
    upd = (x[:, :, None, :] * in_cap[..., None].astype(x.dtype))
    xin = jnp.zeros((b, e, cap, d), x.dtype).at[
        b_idx, idx, pos_c].add(upd)                           # (B, E, C, d)

    def _pin(t, expert_axis):
        if cfg.dispatch_spec is None:
            return t
        from jax.sharding import PartitionSpec as P
        dp, eax = cfg.dispatch_spec
        axes = [tuple(dp)] + [None] * (t.ndim - 1)
        if expert_axis:
            axes[1] = eax
        return jax.lax.with_sharding_constraint(t, P(*axes))

    xin = _pin(xin, True)
    h = jnp.einsum("becd,edf->becf", xin, params["w_gate"])
    hu = jnp.einsum("becd,edf->becf", xin, params["w_up"])
    h = jax.nn.silu(h) * hu
    xout = _pin(jnp.einsum("becf,efd->becd", h, params["w_out"]), True)
    gathered = _pin(xout[b_idx, idx, pos_c], False)           # (B, L, K, d)
    gates = (gate_vals * in_cap.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("blkd,blk->bld", gathered, gates)
    return out, aux
