"""The composable LM stack: config, init, train/prefill/decode entrypoints.

Supports heterogeneous block patterns (dense attention, sliding-window
attention, RG-LRU, RWKV-6), GQA, MoE FFNs, qk-norm, RoPE, tied heads,
text/audio/VLM modalities — enough to express all 10 assigned architectures
plus the paper's Gemma-style model, with the paper's RF attention selectable
per config (FeatureConfig.kind).

Layer stacking: the block pattern repeats over the depth; full repetitions
are stacked and executed with jax.lax.scan (keeps HLO size and compile time
independent of depth — essential for the 512-device dry-run), any remainder
layers run unscanned. Each scanned unit is wrapped in jax.checkpoint with a
configurable remat policy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import feature_maps as fm
from repro.models import layers as ll
from repro.models import attention_block as ab
from repro.models import recurrent as rec

Array = jax.Array

REMAT_POLICIES = {
    "none": None,
    "full": "nothing_saveable",
    "dots": "dots_with_no_batch_dims_saveable",
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                       # 0 -> d_model // n_heads
    block_pattern: tuple = ("attn",)      # cycled: attn|local|rec|rwkv
    attn: fm.FeatureConfig = fm.FeatureConfig(kind="darkformer")
    window: Optional[int] = None          # for "local" blocks
    rope_theta: float = 10000.0           # <=0 disables RoPE
    qk_norm: bool = False
    mlp_kind: str = "swiglu"              # swiglu|geglu|gelu
    moe: Optional[ll.MoEConfig] = None
    tie_embeddings: bool = True
    causal: bool = True
    modality: str = "text"                # text|audio|vlm
    norm_kind: str = "rmsnorm"
    d_rnn: int = 0                        # rec blocks; 0 -> d_model
    embed_scale: bool = False             # gemma-style sqrt(d) embed scale
    logit_softcap: float = 0.0
    num_patches: int = 256                # vlm prefix length
    dtype: str = "float32"                # param/activation dtype
    remat: str = "dots"                   # key of REMAT_POLICIES
    scan_layers: bool = True
    use_kernel: bool = False              # pallas linear-attention path
    z_loss: float = 1e-4
    # Per-arch sharding-rule overrides: ((path-regex, partition-spec-tuple),
    # ...) applied before the global rules in repro.parallel.sharding.
    # Sharding is geometry-dependent; archs whose dims interact badly with
    # the global rules pin their empirically-best layout here (see
    # EXPERIMENTS.md §Perf, granite-moe iterations).
    sharding_overrides: tuple = ()

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def layer_kinds(self) -> list[str]:
        p = self.block_pattern
        return [p[i % len(p)] for i in range(self.n_layers)]

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def n_rem(self) -> int:
        return self.n_layers % len(self.block_pattern)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str) -> dict:
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": ll.norm_init(cfg.norm_kind, cfg.d_model, dt),
                         "ln2": ll.norm_init(cfg.norm_kind, cfg.d_model, dt)}
    if kind in ("attn", "local"):
        p["attn"] = ab.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                 cfg.head_dim, cfg.attn, cfg.qk_norm, dt)
        p["ffn"] = (ll.moe_init(k2, cfg.d_model, cfg.moe, dt)
                    if cfg.moe else
                    ll.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dt))
    elif kind == "rec":
        p["rec"] = rec.rglru_init(k1, cfg.d_model, cfg.rnn_width, dt)
        p["ffn"] = (ll.moe_init(k2, cfg.d_model, cfg.moe, dt)
                    if cfg.moe else
                    ll.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dt))
    elif kind == "rwkv":
        p["tmix"] = rec.rwkv6_init(k1, cfg.d_model, cfg.n_heads, dtype=dt)
        p["cmix"] = rec.rwkv6_channel_mix_init(k2, cfg.d_model, cfg.d_ff, dt)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def _unit_init(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{i}": _block_init(keys[i], cfg, kind)
            for i, kind in enumerate(cfg.block_pattern)}


def init_params(key, cfg: ModelConfig) -> dict:
    dt = cfg.param_dtype
    ke, ku, kr, kh, kp = jax.random.split(key, 5)
    params: dict[str, Any] = {
        "embed": ll.trunc_normal(ke, (cfg.vocab, cfg.d_model), 1.0, dt),
        "final_norm": ll.norm_init(cfg.norm_kind, cfg.d_model, dt),
    }
    if cfg.n_units > 0:
        unit_keys = jax.random.split(ku, cfg.n_units)
        params["units"] = jax.vmap(
            lambda k: _unit_init(k, cfg))(unit_keys)
    if cfg.n_rem:
        rem_keys = jax.random.split(kr, cfg.n_rem)
        params["rem"] = [
            _block_init(rem_keys[i], cfg,
                        cfg.block_pattern[i % len(cfg.block_pattern)])
            for i in range(cfg.n_rem)]
    if not cfg.tie_embeddings:
        params["lm_head"] = ll.trunc_normal(kh, (cfg.d_model, cfg.vocab),
                                            1.0, dt)
    if cfg.modality == "audio":
        params["mask_embed"] = ll.trunc_normal(kp, (cfg.d_model,), 1.0, dt)
    return params


# ---------------------------------------------------------------------------
# Block application (train / prefill: full-sequence)
# ---------------------------------------------------------------------------

def _apply_block(params, x, cfg: ModelConfig, kind: str, *,
                 layer_key: Optional[Array], state=None, mode="train",
                 position=None, valid_len=None, proj=None):
    """Returns (x, aux_loss, new_state).

    ``valid_len`` ((B,) int32, prefill mode only) marks ragged rows of a
    padded multi-admission chunk; every stateful mixer masks its carry so
    padded positions leave no trace (see the per-mixer docstrings).
    ``proj`` (prefill / decode modes) is the block's precomposed serve
    projection selecting the fused megakernel path under
    ``cfg.use_kernel`` (prefill: ``prf_fused_prefill``; decode:
    ``prf_fused_decode``).
    """
    aux = jnp.zeros((), jnp.float32)
    h = ll.apply_norm(cfg.norm_kind, params["ln1"], x)
    new_state = state
    common = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
                  qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta)
    window = cfg.window if kind == "local" else None
    if kind in ("attn", "local"):
        if mode == "train":
            mix = ab.attn_apply(params["attn"], h, cfg.attn, causal=cfg.causal,
                                window=window, use_kernel=cfg.use_kernel,
                                baseline_key=layer_key, **common)
        elif mode == "prefill":
            # state is the block's incoming serve state; position the
            # chunk's start offset — prefill is a resumable multi-token
            # step, exactly parallel to decode.
            mix, new_state = ab.attn_prefill(
                params["attn"], h, cfg.attn, window=window,
                state=state, position=position, valid_len=valid_len,
                use_kernel=cfg.use_kernel, proj=proj, **common)
        else:  # decode
            mix, new_state = ab.attn_decode(
                params["attn"], h, state, cfg.attn, position=position,
                window=window, use_kernel=cfg.use_kernel, proj=proj,
                **common)
        x = x + mix
        h2 = ll.apply_norm(cfg.norm_kind, params["ln2"], x)
        if cfg.moe:
            f, aux = ll.moe_apply(params["ffn"], h2, cfg.moe)
        else:
            f = ll.mlp_apply(params["ffn"], h2, cfg.mlp_kind)
        x = x + f
    elif kind == "rec":
        if mode == "train":
            mix, _ = rec.rglru_apply(params["rec"], h, None)
        else:                       # prefill chunk / decode: carry state
            mix, new_state = rec.rglru_apply(params["rec"], h, state,
                                             valid_len=valid_len)
        x = x + mix
        h2 = ll.apply_norm(cfg.norm_kind, params["ln2"], x)
        if cfg.moe:
            f, aux = ll.moe_apply(params["ffn"], h2, cfg.moe)
        else:
            f = ll.mlp_apply(params["ffn"], h2, cfg.mlp_kind)
        x = x + f
    elif kind == "rwkv":
        if mode == "train":
            mix, _ = rec.rwkv6_apply(params["tmix"], h, cfg.n_heads, None)
            x = x + mix
            h2 = ll.apply_norm(cfg.norm_kind, params["ln2"], x)
            f, _ = rec.rwkv6_channel_mix(params["cmix"], h2, None)
            x = x + f
        else:                       # prefill chunk / decode: carry state
            tstate, cshift = state
            mix, tstate = rec.rwkv6_apply(params["tmix"], h, cfg.n_heads,
                                          tstate, valid_len=valid_len)
            x = x + mix
            h2 = ll.apply_norm(cfg.norm_kind, params["ln2"], x)
            f, cshift = rec.rwkv6_channel_mix(params["cmix"], h2, cshift,
                                              valid_len=valid_len)
            x = x + f
            new_state = (tstate, cshift)
    return x, aux, new_state


def _embed_inputs(params, cfg: ModelConfig, batch: dict) -> Array:
    dt = cfg.param_dtype
    if cfg.modality == "audio":
        x = batch["frames"].astype(dt)
        if "mask" in batch:
            me = params["mask_embed"].astype(dt)
            x = jnp.where(batch["mask"][..., None], me[None, None], x)
        return x
    tok = params["embed"][batch["tokens"]]
    if cfg.embed_scale:
        tok = tok * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.modality == "vlm":
        patches = batch["patch_embeds"].astype(dt)
        return jnp.concatenate([patches, tok.astype(dt)], axis=1)
    return tok.astype(dt)


def _logits(params, cfg: ModelConfig, x: Array) -> Array:
    x = ll.apply_norm(cfg.norm_kind, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def forward_train(params, cfg: ModelConfig, batch: dict,
                  rng: Optional[Array] = None) -> tuple[Array, Array]:
    """Full forward. Returns (logits (B, L, V), aux_loss)."""
    x = _embed_inputs(params, cfg, batch)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    aux_total = jnp.zeros((), jnp.float32)

    def unit_body(x, xs):
        unit_params, uidx = xs
        aux_u = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.block_pattern):
            lk = jax.random.fold_in(rng, uidx * 16 + i)
            x, aux, _ = _apply_block(unit_params[f"b{i}"], x, cfg, kind,
                                     layer_key=lk, mode="train")
            aux_u = aux_u + aux
        return x, aux_u

    if cfg.n_units > 0:
        body = unit_body
        policy = REMAT_POLICIES[cfg.remat]
        if policy is not None:
            pol = (getattr(jax.checkpoint_policies, policy)
                   if policy != "nothing_saveable"
                   else jax.checkpoint_policies.nothing_saveable)
            body = jax.checkpoint(unit_body, policy=pol,
                                  prevent_cse=not cfg.scan_layers)
        if cfg.scan_layers:
            x, auxs = jax.lax.scan(
                body, x, (params["units"], jnp.arange(cfg.n_units)))
            aux_total = aux_total + jnp.sum(auxs)
        else:
            units = params["units"]
            for u in range(cfg.n_units):
                up = jax.tree_util.tree_map(lambda a: a[u], units)
                x, aux_u = body(x, (up, jnp.asarray(u)))
                aux_total = aux_total + aux_u
    for i in range(cfg.n_rem):
        kind = cfg.block_pattern[i % len(cfg.block_pattern)]
        lk = jax.random.fold_in(rng, 10_000 + i)
        x, aux, _ = _apply_block(params["rem"][i], x, cfg, kind,
                                 layer_key=lk, mode="train")
        aux_total = aux_total + aux
    return _logits(params, cfg, x), aux_total


def collect_qk(params, cfg: ModelConfig, batch: dict) -> dict:
    """Run the stack and capture post-RoPE q/k of every attention block.

    Calibration tap for the whitening init (App. C): returns
    {"unit<u>/b<i>": (q, k)} with q: (B, G, Hg, L, dh), k: (B, G, 1, L, dh).
    Runs the layer loop in Python (no scan) — intended for the reduced /
    bench-scale models used in calibration passes.
    """
    x = _embed_inputs(params, cfg, batch)
    taps: dict = {}
    kinds = cfg.layer_kinds()
    plen = len(cfg.block_pattern)

    def get_block_params(li: int):
        u, i = divmod(li, plen)
        if u < cfg.n_units:
            return jax.tree_util.tree_map(lambda a: a[u],
                                          params["units"])[f"b{i}"], u, i
        return params["rem"][li - cfg.n_units * plen], u, i

    for li, kind in enumerate(kinds):
        bp, u, i = get_block_params(li)
        if kind in ("attn", "local"):
            h = ll.apply_norm(cfg.norm_kind, bp["ln1"], x)
            q, k, _ = ab._project(bp["attn"], h, cfg.n_heads, cfg.n_kv,
                                  cfg.head_dim, cfg.qk_norm,
                                  jnp.arange(h.shape[1]), cfg.rope_theta)
            taps[f"unit{u}/b{i}"] = (q, k)
        x, _, _ = _apply_block(bp, x, cfg, kind,
                               layer_key=jax.random.PRNGKey(li),
                               mode="train")
    return taps


def whitening_calibrate(params, cfg: ModelConfig, batch: dict,
                        shrink: float = 0.05):
    """Set every darkformer m_mat to Lambda^{-1/2} from a calibration batch
    (scaled q/k statistics; the d^{-1/4} temperature is absorbed so the
    covariance matches what the feature map actually sees)."""
    from repro.core import calibration as cal
    if cfg.attn.kind != "darkformer":
        return params
    taps = collect_qk(params, cfg, batch)
    scale = cfg.head_dim ** -0.25
    new = jax.tree_util.tree_map(lambda a: a, params)
    plen = len(cfg.block_pattern)
    for name, (q, k) in taps.items():
        u = int(name.split("/")[0][4:])
        bi = name.split("/")[1]
        if u < cfg.n_units:
            fp = new["units"][bi]["attn"]["feat"]
        else:
            fp = new["rem"][u * plen + int(bi[1:])
                            - cfg.n_units * plen]["attn"]["feat"]
        g = fp["m_mat"].shape[-3] if fp["m_mat"].ndim > 2 else \
            fp["m_mat"].shape[0]
        r = fp["m_mat"].shape[-2]
        mats = []
        for gi in range(q.shape[1]):
            mats.append(cal.whiten_m_from_qk(
                q[:, gi] * scale, k[:, gi] * scale, r, shrink))
        m_new = jnp.stack(mats)
        if fp["m_mat"].ndim > 2 and u < cfg.n_units:
            fp["m_mat"] = fp["m_mat"].at[u].set(
                m_new.astype(fp["m_mat"].dtype))
        else:
            fp["m_mat"] = m_new.astype(fp["m_mat"].dtype)
    return new


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch: dict,
            rng: Optional[Array] = None) -> tuple[Array, dict]:
    logits, aux = forward_train(params, cfg, batch, rng)
    labels = batch["labels"]
    if cfg.modality == "vlm":
        logits = logits[:, -labels.shape[1]:]        # loss on text positions
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll_tok = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0] - logz
    if cfg.modality == "audio" and "mask" in batch:
        wmask = batch["mask"].astype(jnp.float32)
    else:
        wmask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(wmask), 1.0)
    ce = -jnp.sum(ll_tok * wmask) / denom
    zl = cfg.z_loss * jnp.sum(jnp.square(logz) * wmask) / denom
    loss = ce + zl + aux
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * wmask) / denom
    return loss, {"loss": loss, "ce": ce, "z_loss": zl, "aux": aux,
                  "accuracy": acc}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------
#
# Layer-stacked serving layout: a HOMOGENEOUS block pattern (every layer
# the same kind — the darkformer/performer/exact/rwkv configs) collapses
# the per-unit {"b0", "b1", ...} trees into ONE tree whose leaves carry a
# leading (n_layers,) axis, and the jitted serving steps lax.scan a
# single compiled layer body over it. One executable regardless of
# depth: compile time and per-token dispatch overhead stop scaling with
# L. Heterogeneous patterns (recurrentgemma's ("rec","rec","local"))
# keep the per-unit scan with the pattern unrolled inside the body.
# A stacked serve state holds the layer tree under state["layers"]
# instead of state["units"]/state["rem"] (the slot axis moves to 1 for
# every layer leaf — repro/serving/slots.py and
# repro.parallel.serve_state_specs understand both layouts).


def can_stack_layers(cfg: ModelConfig) -> bool:
    """True when every layer is the same block kind (and scanned), so
    serving states and params can stack along one leading layer axis."""
    return (cfg.scan_layers and cfg.n_units > 0 and cfg.n_rem == 0
            and len(set(cfg.block_pattern)) == 1)


def stack_layer_params(params: dict, cfg: ModelConfig) -> dict:
    """One block tree with leaves (n_layers, ...): layer u*k + i is
    pattern position i of unit u. For the common k = 1 patterns this is
    just ``params["units"]["b0"]`` — no copy. For k > 1 the interleave
    materializes a stacked copy, so engines stack ONCE at build and
    pass it back through ``params["layers"]`` (the serving steps prefer
    that key over re-stacking per call)."""
    units = params["units"]
    k = len(cfg.block_pattern)
    if k == 1:
        return units["b0"]

    def interleave(*leaves):
        st = jnp.stack(leaves, axis=1)             # (U, k, ...)
        return st.reshape((-1,) + st.shape[2:])
    return jax.tree_util.tree_map(
        interleave, *[units[f"b{i}"] for i in range(k)])


def build_decode_proj(params: dict, cfg: ModelConfig,
                      stacked: bool = False) -> Optional[dict]:
    """Precompose every attention layer's serve projection A = (W M)^T
    (``fm.precompose_projection``) — ONCE, at engine build, so the fused
    decode AND prefill megakernels never re-derive it per step. Returns
    a pytree mirroring the serve-state layout ({"layers": ...} when
    ``stacked``, else {"units": {"b<i>": ...}, "rem": [...]} with None
    at non-PRF blocks), or None when the config has no fused path.

    ``decode_step`` / ``prefill_chunk`` build this on the fly when not
    given one (inside the caller's jit — same composition, bit-identical
    A), so engines that precompute and engines that don't agree exactly.
    """
    if not (cfg.use_kernel and cfg.attn.kind in fm.PRF_KINDS):
        return None
    if not any(k in ("attn", "local") for k in cfg.layer_kinds()):
        return None
    if stacked:
        sp = (params["layers"] if "layers" in params
              else stack_layer_params(params, cfg))
        return {"layers": fm.precompose_projection(sp["attn"]["feat"],
                                                   cfg.attn.kind)}
    proj: dict[str, Any] = {}
    if cfg.n_units > 0:
        proj["units"] = {
            f"b{i}": (fm.precompose_projection(
                params["units"][f"b{i}"]["attn"]["feat"], cfg.attn.kind)
                if kind in ("attn", "local") else None)
            for i, kind in enumerate(cfg.block_pattern)}
    if cfg.n_rem:
        proj["rem"] = [
            (fm.precompose_projection(params["rem"][i]["attn"]["feat"],
                                      cfg.attn.kind)
             if cfg.block_pattern[i % len(cfg.block_pattern)]
             in ("attn", "local") else None)
            for i in range(cfg.n_rem)]
    return proj


def _init_block_state(cfg: ModelConfig, kind: str, b: int, max_len: int,
                      per_slot: bool = False):
    if kind in ("attn", "local"):
        return ab.init_attn_serve_state(
            cfg.attn, b, cfg.n_heads, cfg.n_kv, cfg.head_dim, max_len,
            cfg.window if kind == "local" else None, per_slot=per_slot)
    if kind == "rec":
        return rec.init_rglru_state(b, cfg.rnn_width)
    if kind == "rwkv":
        return (rec.init_rwkv_state(b, cfg.d_model, cfg.n_heads),
                jnp.zeros((b, cfg.d_model), jnp.float32))
    raise ValueError(kind)


def init_serve_state(cfg: ModelConfig, b: int, max_len: int,
                     per_slot: bool = False,
                     stacked: bool = False) -> dict:
    """Initial serving state for a batch of b sequences.

    ``per_slot`` turns the state into a continuous-batching slot pool:
    ``pos`` (and the exact-attention cache lengths) become (b,) vectors so
    every batch row advances independently (see repro.serving).

    ``stacked`` (requires :func:`can_stack_layers`) lays the per-layer
    states along ONE leading (n_layers,) axis under ``state["layers"]``
    so the serving steps scan a single layer body — the engine's layout
    for homogeneous configs.
    """
    state: dict[str, Any] = {}
    if stacked:
        if not can_stack_layers(cfg):
            raise ValueError(
                f"{cfg.name}: stacked serve states need a homogeneous "
                f"scanned block pattern (got {cfg.block_pattern}, "
                f"n_rem={cfg.n_rem}, scan_layers={cfg.scan_layers})")
        kind0 = cfg.block_pattern[0]
        state["layers"] = jax.vmap(
            lambda _: _init_block_state(cfg, kind0, b, max_len,
                                        per_slot))(
            jnp.arange(cfg.n_layers))
        state["pos"] = jnp.zeros((b,) if per_slot else (), jnp.int32)
        return state
    if cfg.n_units > 0:
        def one_unit(_):
            return {f"b{i}": _init_block_state(cfg, kind, b, max_len,
                                               per_slot)
                    for i, kind in enumerate(cfg.block_pattern)}
        state["units"] = jax.vmap(one_unit)(jnp.arange(cfg.n_units))
    if cfg.n_rem:
        state["rem"] = [
            _init_block_state(
                cfg, cfg.block_pattern[i % len(cfg.block_pattern)], b,
                max_len, per_slot)
            for i in range(cfg.n_rem)]
    state["pos"] = jnp.zeros((b,) if per_slot else (), jnp.int32)
    return state


def init_paged_serve_state(cfg: ModelConfig, b: int, max_len: int,
                           page_size: int) -> dict:
    """Slot pool for the block-granular paged exact-KV layout.

    Exact + layer-stacked only: each row carries a (max_pages,) page
    table and a write index per layer; the shared page pools come from
    :func:`init_kv_pages` and are attached around each jitted step
    (:func:`attach_kv_pages`). Slot ops see only the detached tree (the
    None kv leaves are skipped), so admission/commit/freeze scatter
    tables and lengths — never pages: forking a cached prefix into N
    rows copies page IDS, not keys/values
    (repro/serving/prefix_cache.py)."""
    if cfg.attn.kind != "exact" or not can_stack_layers(cfg):
        raise ValueError(
            f"{cfg.name}: paged KV serve states need an exact-attention "
            f"layer-stacked config (kind={cfg.attn.kind}, "
            f"stackable={can_stack_layers(cfg)})")
    max_pages = -(-max_len // page_size)
    state = {"layers": jax.vmap(
        lambda _: ab.init_paged_attn_state(b, max_pages))(
        jnp.arange(cfg.n_layers)),
        "pos": jnp.zeros((b,), jnp.int32)}
    return state


def init_kv_pages(cfg: ModelConfig, n_pages: int, page_size: int) -> dict:
    """Shared per-layer exact-KV page pools: {"k", "v"} each
    (n_layers, n_pages, page_size, G, d_head). Page 0 is the reserved
    garbage page masked/inactive writes are routed to."""
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, jnp.float32),
            "v": jnp.zeros(shape, jnp.float32)}


def attach_kv_pages(state: dict, pages: dict) -> dict:
    """Graft the shared page pools into a detached paged serve state so
    ``decode_step`` / ``prefill_chunk`` can run it: the per-layer scan
    slices pages along the leading layer axis exactly like every other
    state leaf."""
    return {**state,
            "layers": state["layers"]._replace(kv_k=pages["k"],
                                               kv_v=pages["v"])}


def detach_kv_pages(state: dict) -> tuple[dict, dict]:
    """Inverse of :func:`attach_kv_pages`: split an advanced state back
    into (detached slot-pool tree, updated page pools)."""
    la = state["layers"]
    pages = {"k": la.kv_k, "v": la.kv_v}
    return ({**state, "layers": la._replace(kv_k=None, kv_v=None)},
            pages)


def prefill_chunk(params, cfg: ModelConfig, batch: dict, state: dict,
                  valid_len: Optional[Array] = None,
                  proj: Optional[dict] = None,
                  fused: bool = True) -> tuple[Array, dict]:
    """Advance a serve state over one prompt chunk.

    ``state`` is a serve state from :func:`init_serve_state` (fresh) or a
    previous ``prefill_chunk`` call — its ``pos`` (() or (B,) int32) is
    the chunk's start offset, threaded to every layer (RoPE rotations,
    exact-cache write indices, recurrent carries). Returns
    (last-position logits (B, V), advanced state). This is the resume
    point the chunked-prefill scheduler interleaves with decode steps
    (repro/serving/engine.py); whole-prompt :func:`prefill` is the
    degenerate one-chunk schedule.

    ``valid_len`` ((B,) int32) makes the chunk *ragged*: row b consumes
    only its first ``valid_len[b]`` tokens — the rest are padding that
    leaves no trace in the advanced state (masked PRF (S, z) updates,
    per-row exact-cache append lengths, masked RG-LRU/RWKV carries), and
    the returned logits are gathered at each row's last valid position.
    This is what lets the serving engine pad several staged admissions'
    chunks into ONE batched (B, L) call. A chunk whose rows are ALL full
    should pass ``valid_len=None``: the masked path is mathematically the
    identity then, but XLA may fuse it differently (f32-close, not
    bitwise) — the engine does exactly this for its exactness contract.

    With ``cfg.use_kernel`` and a PRF kind the chunk runs the fused
    ``prf_fused_prefill`` megakernel — ONE pallas_call per layer per
    packed chunk, valid_len masked in-kernel, (S, z, c) aliased in
    place. ``proj`` is the precomposed per-layer projection pytree
    (:func:`build_decode_proj`) — pass the engine-built one to keep the
    M·Wᵀ composition off the per-chunk path, or leave None to compose
    inside the call (bit-identical output). ``fused=False`` forces the
    legacy two-stage path (jnp featmap + carry-scan kernel — the oracle
    the megakernel is tested against).
    """
    x = _embed_inputs(params, cfg, batch)
    pos = state["pos"]
    adv = x.shape[1] if valid_len is None else valid_len
    new_state: dict[str, Any] = {"pos": pos + adv}
    if proj is None and fused:
        proj = build_decode_proj(params, cfg, stacked="layers" in state)
    elif not fused:
        proj = None

    if "layers" in state:                  # layer-stacked homogeneous
        kind0 = cfg.block_pattern[0]
        sp = (params["layers"] if "layers" in params
              else stack_layer_params(params, cfg))
        proj_l = None if proj is None else proj["layers"]

        def layer_body(x, xs):
            layer_params, layer_state, layer_proj = xs
            x, _, st = _apply_block(layer_params, x, cfg, kind0,
                                    layer_key=None, state=layer_state,
                                    mode="prefill", position=pos,
                                    valid_len=valid_len, proj=layer_proj)
            return x, st

        x, layer_states = jax.lax.scan(layer_body, x,
                                       (sp, state["layers"], proj_l))
        new_state["layers"] = layer_states
        if valid_len is None:
            x_last = x[:, -1:]
        else:
            x_last = jnp.take_along_axis(
                x, jnp.maximum(valid_len - 1, 0)[:, None, None], axis=1)
        return _logits(params, cfg, x_last)[:, 0], new_state

    proj_units = (proj or {}).get("units") or \
        {f"b{i}": None for i in range(len(cfg.block_pattern))}

    def unit_body(x, xs):
        unit_params, unit_state, unit_proj = xs
        new_states = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, _, st = _apply_block(unit_params[f"b{i}"], x, cfg, kind,
                                    layer_key=None,
                                    state=unit_state[f"b{i}"],
                                    mode="prefill", position=pos,
                                    valid_len=valid_len,
                                    proj=unit_proj[f"b{i}"])
            new_states[f"b{i}"] = st
        return x, new_states

    if cfg.n_units > 0:
        if cfg.scan_layers:
            x, unit_states = jax.lax.scan(
                unit_body, x, (params["units"], state["units"],
                               proj_units))
            new_state["units"] = unit_states
        else:
            per_unit = []
            for u in range(cfg.n_units):
                sl = jax.tree_util.tree_map(lambda a: a[u],
                                            (params["units"],
                                             state["units"],
                                             proj_units))
                x, st_u = unit_body(x, sl)
                per_unit.append(st_u)
            new_state["units"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per_unit)
    if cfg.n_rem:
        rem_proj = (proj or {}).get("rem") or [None] * cfg.n_rem
        new_state["rem"] = []
        for i in range(cfg.n_rem):
            kind = cfg.block_pattern[i % len(cfg.block_pattern)]
            x, _, st = _apply_block(params["rem"][i], x, cfg, kind,
                                    layer_key=None, state=state["rem"][i],
                                    mode="prefill", position=pos,
                                    valid_len=valid_len,
                                    proj=rem_proj[i])
            new_state["rem"].append(st)
    if valid_len is None:
        x_last = x[:, -1:]
    else:                          # per-row last-valid-token gather
        x_last = jnp.take_along_axis(
            x, jnp.maximum(valid_len - 1, 0)[:, None, None], axis=1)
    return _logits(params, cfg, x_last)[:, 0], new_state


def prefill(params, cfg: ModelConfig, batch: dict, max_len: int
            ) -> tuple[Array, dict]:
    """Full-prompt pass; returns ((B, 1, V) last logits, serve state).

    One whole-prompt ``prefill_chunk`` from a fresh serve state — the
    degenerate chunking schedule, so chunked and blocking admission share
    a single mechanism.
    """
    b = (batch["frames"] if cfg.modality == "audio"
         else batch["tokens"]).shape[0]
    state = init_serve_state(cfg, b=b, max_len=max_len)
    logits, state = prefill_chunk(params, cfg, batch, state)
    return logits[:, None], state


def decode_step(params, cfg: ModelConfig, token: Array, state: dict,
                proj: Optional[dict] = None, fused: bool = True
                ) -> tuple[Array, dict]:
    """One serving step. token: (B,) int32 -> (logits (B, V), new state).

    With ``cfg.use_kernel`` and a PRF kind, decode runs the fused
    megakernel; ``proj`` is the precomposed per-layer projection pytree
    (:func:`build_decode_proj`) — pass the engine-built one to keep the
    M·Wᵀ composition off the per-token path, or leave None to compose
    inside the step (bit-identical output). ``fused=False`` forces the
    legacy two-stage kernel path (the oracle the megakernel is tested
    against). A ``state`` from ``init_serve_state(stacked=True)`` runs
    one scanned layer body over the stacked layer axis.
    """
    pos = state["pos"]
    x = params["embed"][token][:, None]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = x.astype(cfg.param_dtype)
    new_state: dict[str, Any] = {"pos": pos + 1}
    if proj is None and fused:
        proj = build_decode_proj(params, cfg, stacked="layers" in state)
    elif not fused:
        proj = None

    if "layers" in state:                  # layer-stacked homogeneous
        kind0 = cfg.block_pattern[0]
        sp = (params["layers"] if "layers" in params
              else stack_layer_params(params, cfg))
        proj_l = None if proj is None else proj["layers"]

        def layer_body(x, xs):
            layer_params, layer_state, layer_proj = xs
            x, _, st = _apply_block(layer_params, x, cfg, kind0,
                                    layer_key=None, state=layer_state,
                                    mode="decode", position=pos,
                                    proj=layer_proj)
            return x, st

        x, layer_states = jax.lax.scan(
            layer_body, x, (sp, state["layers"], proj_l))
        new_state["layers"] = layer_states
        return _logits(params, cfg, x)[:, 0], new_state

    proj_units = (proj or {}).get("units") or \
        {f"b{i}": None for i in range(len(cfg.block_pattern))}

    def unit_body(x, xs):
        unit_params, unit_state, unit_proj = xs
        new_states = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, _, st = _apply_block(unit_params[f"b{i}"], x, cfg, kind,
                                    layer_key=None,
                                    state=unit_state[f"b{i}"],
                                    mode="decode", position=pos,
                                    proj=unit_proj[f"b{i}"])
            new_states[f"b{i}"] = st
        return x, new_states

    if cfg.n_units > 0:
        if cfg.scan_layers:
            x, unit_states = jax.lax.scan(
                unit_body, x, (params["units"], state["units"],
                               proj_units))
            new_state["units"] = unit_states
        else:
            per_unit = []
            for u in range(cfg.n_units):
                sl = jax.tree_util.tree_map(lambda a: a[u],
                                            (params["units"],
                                             state["units"],
                                             proj_units))
                x, st_u = unit_body(x, sl)
                per_unit.append(st_u)
            new_state["units"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per_unit)
    if cfg.n_rem:
        rem_proj = (proj or {}).get("rem") or [None] * cfg.n_rem
        new_state["rem"] = []
        for i in range(cfg.n_rem):
            kind = cfg.block_pattern[i % len(cfg.block_pattern)]
            x, _, st = _apply_block(params["rem"][i], x, cfg, kind,
                                    layer_key=None, state=state["rem"][i],
                                    mode="decode", position=pos,
                                    proj=rem_proj[i])
            new_state["rem"].append(st)
    return _logits(params, cfg, x)[:, 0], new_state
