"""Synthetic LM/audio/VLM data with learnable structure.

Design requirements (framework-grade, not toy):
  * deterministic: batch(step) is a pure function of (seed, step, host) —
    restart/resume replays the exact stream with no iterator state to save;
  * shardable: hosts get disjoint substreams (seed folded with host id);
  * learnable: tokens follow a sparse bigram process (each token has a small
    successor set derived from a hash) mixed with uniform noise, so
    next-token accuracy rises well above chance within a few hundred steps
    and the paper's relative comparisons (dark vs performer vs exact) are
    meaningful;
  * host-side numpy generation (no XLA compilation in the input pipeline —
    keeps the data path off the accelerator compile queue, which is also
    what a production loader does).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _rng(seed: int, host: int, step: int, salt: int = 0):
    return np.random.default_rng(
        np.random.SeedSequence([seed, host, step, salt]))


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    batch_size: int            # per-host batch
    seed: int = 0
    host: int = 0
    branching: int = 4         # successors per token
    noise: float = 0.1         # P(uniform token)
    task: str = "bigram"       # bigram | induction
    alphabet: int = 32         # induction: symbols drawn per sequence

    def _successors(self) -> np.ndarray:
        """(vocab, branching) int32 successor table via a hash mix."""
        t = np.arange(self.vocab, dtype=np.uint32)[:, None]
        b = np.arange(self.branching, dtype=np.uint32)[None, :]
        h = (t * np.uint32(2654435761) + b * np.uint32(40503)
             + np.uint32(self.seed * 97 + 13))
        h = (h ^ (h >> np.uint32(15))) * np.uint32(2246822519)
        h = h ^ (h >> np.uint32(13))
        return (h % np.uint32(self.vocab)).astype(np.int32)

    def batch(self, step: int) -> dict:
        """Returns {"tokens": (B, L), "labels": (B, L)} — labels are the
        next token (teacher forcing), last label wraps to the first.

        task="induction": in-context copying (the induction-head task).
        Tokens are drawn from a small per-batch alphabet so symbols repeat;
        whenever x[t] occurred before at position s, the next token is
        forced to x[s+1] and the label at t is x[s+1]; other positions are
        label-masked (-1). Solving it REQUIRES attention to the previous
        occurrence — FFN memorization cannot help (associations are random
        per sequence), so attention-kernel quality is what's measured."""
        if self.task == "induction":
            return self._induction_batch(step)
        rng = _rng(self.seed, self.host, step)
        succ = self._successors()
        b, l = self.batch_size, self.seq_len
        cur = rng.integers(0, self.vocab, b).astype(np.int32)
        toks = np.empty((b, l), np.int32)
        branch = rng.integers(0, self.branching, (l, b))
        use_noise = rng.random((l, b)) < self.noise
        uni = rng.integers(0, self.vocab, (l, b)).astype(np.int32)
        for t in range(l):
            nxt = succ[cur, branch[t]]
            cur = np.where(use_noise[t], uni[t], nxt).astype(np.int32)
            toks[:, t] = cur
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        return {"tokens": toks, "labels": labels}

    def _induction_batch(self, step: int) -> dict:
        rng = _rng(self.seed, self.host, step, salt=3)
        b, l = self.batch_size, self.seq_len
        toks = np.empty((b, l), np.int32)
        labels = np.full((b, l), -1, np.int32)
        for i in range(b):
            alpha = rng.choice(self.vocab, self.alphabet, replace=False)
            seq = alpha[rng.integers(0, self.alphabet, l)]
            last_pos: dict[int, int] = {}
            for t in range(l):
                cur = int(seq[t])
                s = last_pos.get(cur)
                if s is not None and s + 1 < t:
                    seq[t + 1 if t + 1 < l else t] = seq[s + 1]
                    if t + 1 < l:
                        labels[i, t] = seq[s + 1]
                last_pos[cur] = t
            toks[i] = seq
        return {"tokens": toks, "labels": labels}


@dataclasses.dataclass(frozen=True)
class SyntheticAudio:
    """Masked-frame-prediction batches for the HuBERT-style encoder."""
    d_model: int
    seq_len: int
    batch_size: int
    vocab: int = 504
    seed: int = 0
    host: int = 0
    mask_prob: float = 0.3

    def batch(self, step: int) -> dict:
        rng = _rng(self.seed, self.host, step, salt=1)
        b, l = self.batch_size, self.seq_len
        labels = rng.integers(0, self.vocab, (b, l)).astype(np.int32)
        # frames carry a noisy linear signature of the label so the task
        # is learnable.
        dirs = np.random.default_rng(
            np.random.SeedSequence([self.seed + 7])).standard_normal(
            (self.vocab, self.d_model)).astype(np.float32)
        frames = dirs[labels] + 0.5 * rng.standard_normal(
            (b, l, self.d_model)).astype(np.float32)
        mask = rng.random((b, l)) < self.mask_prob
        return {"frames": frames.astype(np.float32), "mask": mask,
                "labels": labels}


@dataclasses.dataclass(frozen=True)
class SyntheticVLM:
    """Patch-prefix + caption-token batches for the VLM backbone."""
    d_model: int
    num_patches: int
    seq_len: int               # text length
    batch_size: int
    vocab: int
    seed: int = 0
    host: int = 0

    def batch(self, step: int) -> dict:
        lm = SyntheticLM(self.vocab, self.seq_len, self.batch_size,
                         seed=self.seed, host=self.host)
        b = lm.batch(step)
        rng = _rng(self.seed + 31, self.host, step, salt=2)
        patches = 0.02 * rng.standard_normal(
            (self.batch_size, self.num_patches,
             self.d_model)).astype(np.float32)
        return {"tokens": b["tokens"], "labels": b["labels"],
                "patch_embeds": patches}
