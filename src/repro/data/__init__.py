"""Deterministic, shardable, resumable data pipelines (no external deps)."""
from repro.data.synthetic import SyntheticLM, SyntheticAudio, SyntheticVLM
from repro.data.c4_mock import C4Mock
