"""C4-mock: a deterministic byte-level pseudo-corpus.

The real paper benches on C4; this container has no datasets, so we emit a
deterministic stream of template-grammar English-ish sentences and tokenize
at the byte level (vocab 256 folded into the model vocab). The stream is a
pure function of (seed, step, host) like SyntheticLM.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_SUBJ = ["the model", "a transformer", "the kernel", "random features",
         "the attention map", "a long sequence", "the optimizer",
         "the data pipeline", "a pretrained network", "the covariance"]
_VERB = ["approximates", "computes", "learns", "reduces", "samples",
         "projects", "normalizes", "whitens", "stabilizes", "scales"]
_OBJ = ["the softmax kernel", "an anisotropic distribution",
        "the feature space", "a low-rank geometry", "the variance",
        "the importance weights", "a mahalanobis metric",
        "the query distribution", "a linear map", "the gradient noise"]
_ADV = ["efficiently", "unbiasedly", "in linear time", "at scale",
        "with low variance", "per head", "after finetuning",
        "during pretraining", "without retraining", "stably"]


@dataclasses.dataclass(frozen=True)
class C4Mock:
    vocab: int
    seq_len: int
    batch_size: int
    seed: int = 0
    host: int = 0

    def _sentence(self, rng: np.random.Generator) -> bytes:
        s = (f"{rng.choice(_SUBJ)} {rng.choice(_VERB)} "
             f"{rng.choice(_OBJ)} {rng.choice(_ADV)}. ")
        return s.encode()

    def batch(self, step: int) -> dict:
        rows = []
        for b in range(self.batch_size):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + self.host * 7919 + step) * 65537
                + b)
            buf = b""
            while len(buf) < self.seq_len + 1:
                buf += self._sentence(rng)
            arr = np.frombuffer(buf[: self.seq_len + 1],
                                dtype=np.uint8).astype(np.int32)
            rows.append(arr % self.vocab)
        mat = np.stack(rows)
        return {"tokens": mat[:, :-1], "labels": mat[:, 1:]}
